from repro.pipeline.executor import (make_pipeline_runner, make_plan_runner,
                                     pipeline_forward, plan_forward,
                                     plan_stage_params, run_stage,
                                     stage_params_reshape)
