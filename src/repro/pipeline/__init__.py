from repro.pipeline.executor import (make_pipeline_runner, pipeline_forward,
                                     stage_params_reshape)
