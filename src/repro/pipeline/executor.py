"""SSR spatial/hybrid runtime executor — GPipe-style microbatch pipeline.

This is the *execution* counterpart of the SSR scheduler: the chosen
Layer→Acc map (contiguous stage partition at runtime) becomes a ``stage``
mesh axis; each stage owns ``num_groups/S`` layer groups whose weights are
sharded onto that stage's submesh; microbatches stream through via
``collective_permute`` over the ICI — the on-chip-forwarding analogue (no
host round trip).  Stage-internal sharding still uses the data/model axes
(they are `auto` axes inside the shard_map), so each "SSR accelerator" is
itself a DPxTP submesh — exactly the paper's Acc-Customization degree of
freedom.

Bubble accounting matches the paper's Fig. 1(b): M microbatches through S
stages take (M + S - 1) stage-times.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.backend import compat
from repro.configs.base import ModelConfig
from repro.models import transformer as T


def stage_params_reshape(stack_params, n_stages: int):
    """(num_groups, ...) stacked params -> (n_stages, groups_per_stage, ...)."""
    def f(x):
        g = x.shape[0]
        assert g % n_stages == 0, (g, n_stages)
        return x.reshape((n_stages, g // n_stages) + x.shape[1:])
    return jax.tree.map(f, stack_params)


def pipeline_spec(stack_params_staged, mesh: Mesh):
    """Shard the leading stage axis over 'stage'; leave the rest to auto."""
    def f(x):
        return NamedSharding(mesh, P("stage"))
    return jax.tree.map(f, stack_params_staged)


def make_pipeline_runner(cfg: ModelConfig, mesh: Mesh, n_stages: int,
                         n_microbatches: int) -> Callable:
    """Returns pipelined(params_staged, x_mb) -> y_mb.

    params_staged: stack params reshaped to (S, G/S, ...), stage-sharded.
    x_mb: (M, mb, seq, d_model) microbatched embedded activations.
    y_mb: (M, mb, seq, d_model) final hidden states.
    """
    S = n_stages
    M = n_microbatches

    def stage_apply(p_local, x):
        y, _, _ = T.run_stack(p_local, x, cfg)
        return y

    def inner(p_local, x_all):
        # p_local leaves: (1, G/S, ...) — this stage's groups.
        p_local = jax.tree.map(lambda a: a[0], p_local)
        stage_id = lax.axis_index("stage")
        state = jnp.zeros_like(x_all[0])
        outputs = jnp.zeros_like(x_all)
        # carries become stage-varying inside the loop: mark them up front
        state = compat.pcast_varying(state, ("stage",))
        outputs = compat.pcast_varying(outputs, ("stage",))

        def tick(t, carry):
            state, outputs = carry
            inp = lax.dynamic_index_in_dim(
                x_all, jnp.clip(t, 0, M - 1), 0, keepdims=False)
            cur = jnp.where(stage_id == 0, inp, state)
            out = stage_apply(p_local, cur)
            # last stage banks its finished microbatch t-(S-1)
            oidx = jnp.clip(t - (S - 1), 0, M - 1)
            prev = lax.dynamic_index_in_dim(outputs, oidx, 0, keepdims=False)
            write = jnp.where((stage_id == S - 1) & (t >= S - 1), out, prev)
            outputs = lax.dynamic_update_index_in_dim(outputs, write, oidx, 0)
            # forward over ICI to the next stage (on-chip forwarding)
            state = lax.ppermute(out, "stage",
                                 [(i, (i + 1) % S) for i in range(S)])
            return state, outputs

        state, outputs = lax.fori_loop(0, M + S - 1, tick, (state, outputs))
        # only the last stage holds real outputs: mask + sum-replicate.
        outputs = jnp.where(stage_id == S - 1, outputs, 0.0)
        outputs = lax.psum(outputs, "stage")
        return outputs

    # Only the manual 'stage' axis appears in specs; data/model sharding of
    # activations is handled by GSPMD (auto axes) outside the shard_map
    # where the installed jax supports partial-manual (compat falls back to
    # full-manual with replicated data/model on 0.4.x).
    batch_in = P(None, None, None, None)
    pipelined = compat.shard_map(
        inner, mesh=mesh,
        in_specs=(P("stage"), batch_in),
        out_specs=batch_in,
        manual_axes=frozenset({"stage"}),
    )
    return pipelined


def pipeline_forward(model, params, batch, mesh: Mesh, n_stages: int,
                     n_microbatches: int):
    """End-to-end SSR-hybrid forward: embed (data-parallel) -> pipelined
    stages -> head.  batch: {'tokens' | 'embeds': ...}."""
    from repro.models import layers as L
    cfg = model.cfg
    if "embeds" in batch:
        x = batch["embeds"].astype(cfg.dtype)
    else:
        x = L.embed(params["embed"], batch["tokens"], cfg).astype(cfg.dtype)
    B, seq, d = x.shape
    M = n_microbatches
    assert B % M == 0, (B, M)
    x_mb = x.reshape(M, B // M, seq, d)

    staged = stage_params_reshape(params["stack"], n_stages)
    runner = make_pipeline_runner(cfg, mesh, n_stages, n_microbatches)
    y_mb = runner(staged, x_mb)
    y = y_mb.reshape(B, seq, d)
    y = L.apply_norm(params["final_norm"], y, cfg)
    return L.logits_head(params.get("embed"), params.get("head"), y, cfg)
