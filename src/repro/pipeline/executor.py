"""SSR spatial/hybrid runtime executor — plan-driven microbatch pipeline.

This is the *execution* counterpart of the SSR scheduler.  The unit of
work is an ``ExecutionPlan`` (``repro.plan``): ordered stage slices over
the scanned layer stack — **not necessarily equal** — each on one slot of
a ``("stage", "data", "model")`` mesh, with ``n_microbatches`` in flight
per round (spatial) and ``n_rounds`` rounds streamed back-to-back
(sequential).  Microbatches move between stages via ``collective_permute``
over the ICI — the on-chip-forwarding analogue (no host round trip).
Stage-internal sharding still uses the data/model axes (they are `auto`
axes inside the shard_map), so each "SSR accelerator" is itself a DPxTP
submesh — exactly the paper's Acc-Customization degree of freedom.

Uneven stages: every stage's parameter stack is padded to
``plan.max_groups`` entries by a clamped gather (repeating the stage's
last real group, so padded compute stays finite) and the dead entries are
masked inside ``run_stack`` — a dead group passes activations through
unchanged.

Bubble accounting matches the paper's Fig. 1(b): M microbatches through S
stages take (M + S - 1) stage-times.

The legacy ``(n_stages, n_microbatches)`` scalar API survives as thin
shims that lower a uniform plan.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.backend import compat
from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.plan.ir import ExecutionPlan, uniform_plan


def stage_params_reshape(stack_params, n_stages: int):
    """(num_groups, ...) stacked params -> (n_stages, groups_per_stage, ...).
    Uniform-split legacy helper; uneven plans use ``plan_stage_params``."""
    def f(x):
        g = x.shape[0]
        assert g % n_stages == 0, (g, n_stages)
        return x.reshape((n_stages, g // n_stages) + x.shape[1:])
    return jax.tree.map(f, stack_params)


def plan_stage_params(stack_params, plan: ExecutionPlan):
    """(num_groups, ...) stacked params -> (S, max_groups, ...) per the
    plan's stage slices: a clamped gather pads short stages by repeating
    their last real group (masked out at runtime)."""
    idx = jnp.asarray(plan.group_index_matrix())
    return jax.tree.map(lambda x: jnp.asarray(x)[idx], stack_params)


def run_stage(cfg: ModelConfig, stage_params, x, *, cache=None,
              cache_index=None, positions=None, collect_state: bool = False,
              group_mask=None, attend_cache: bool = False,
              block_tables=None, write_tables=None):
    """Execute ONE plan stage's (unpadded) group slice — the per-stage
    entry the serving engine steps instead of the whole-plan
    ``plan_forward``.  Returns (y, new_cache, aux).

    stage_params: the stage's group slice of the stacked params (leading
      axis = the stage's n_groups, exact — not padded to max_groups).
    cache / cache_index: the stage's group-range cache slice and token
      offset(s) for prefill / decode stepping (``collect_state=True``
      returns the updated slice).  ``attend_cache=True`` is the
      chunked-prefill continuation mode (fresh chunk attends the cached
      tokens — see ``models.layers.multi_head_attention``).
    group_mask: stateless padded-stage masking (the pipelined forward
      path) — mutually exclusive with ``cache``.
    block_tables: logical->physical page map when ``cache`` is a paged
      (pool-backed) slice — the paged decode stage walk.
    write_tables: fresh-blocks-only page map for paged chunked prefill
      (shared warm blocks carry the sentinel so the chunk's page writes
      drop on them — see ``models.layers.multi_head_attention``).
    """
    return T.run_stack(stage_params, x, cfg, positions=positions,
                       causal=True, cache=cache, cache_index=cache_index,
                       collect_state=collect_state, group_mask=group_mask,
                       attend_cache=attend_cache, block_tables=block_tables,
                       write_tables=write_tables)


def pipeline_spec(stack_params_staged, mesh: Mesh):
    """Shard the leading stage axis over 'stage'; leave the rest to auto."""
    def f(x):
        return NamedSharding(mesh, P("stage"))
    return jax.tree.map(f, stack_params_staged)


def make_plan_runner(cfg: ModelConfig, mesh: Mesh, plan: ExecutionPlan
                     ) -> Callable:
    """Returns pipelined(params_staged, group_mask, x_mb) -> y_mb.

    params_staged: stack params gathered to (S, max_groups, ...) via
      ``plan_stage_params``, stage-sharded.
    group_mask: (S, max_groups) 0/1 — live vs padded groups per stage.
    x_mb: (M_total, mb, seq, d_model) microbatched embedded activations,
      M_total = plan.n_microbatches * plan.n_rounds.
    y_mb: (M_total, mb, seq, d_model) final hidden states.
    """
    S = plan.n_stages
    M = plan.total_microbatches

    def stage_apply(p_local, m_local, x):
        y, _, _ = T.run_stack(p_local, x, cfg, group_mask=m_local)
        return y

    def inner(p_local, m_local, x_all):
        # p_local leaves: (1, max_groups, ...) — this stage's padded groups.
        p_local = jax.tree.map(lambda a: a[0], p_local)
        m_local = m_local[0]
        stage_id = lax.axis_index("stage")
        state = jnp.zeros_like(x_all[0])
        outputs = jnp.zeros_like(x_all)
        # carries become stage-varying inside the loop: mark them up front
        state = compat.pcast_varying(state, ("stage",))
        outputs = compat.pcast_varying(outputs, ("stage",))

        def tick(t, carry):
            state, outputs = carry
            inp = lax.dynamic_index_in_dim(
                x_all, jnp.clip(t, 0, M - 1), 0, keepdims=False)
            cur = jnp.where(stage_id == 0, inp, state)
            out = stage_apply(p_local, m_local, cur)
            # last stage banks its finished microbatch t-(S-1)
            oidx = jnp.clip(t - (S - 1), 0, M - 1)
            prev = lax.dynamic_index_in_dim(outputs, oidx, 0, keepdims=False)
            write = jnp.where((stage_id == S - 1) & (t >= S - 1), out, prev)
            outputs = lax.dynamic_update_index_in_dim(outputs, write, oidx, 0)
            # forward over ICI to the next stage (on-chip forwarding)
            state = lax.ppermute(out, "stage",
                                 [(i, (i + 1) % S) for i in range(S)])
            return state, outputs

        state, outputs = lax.fori_loop(0, M + S - 1, tick, (state, outputs))
        # only the last stage holds real outputs: mask + sum-replicate.
        outputs = jnp.where(stage_id == S - 1, outputs, 0.0)
        outputs = lax.psum(outputs, "stage")
        return outputs

    # Only the manual 'stage' axis appears in specs; data/model sharding of
    # activations is handled by GSPMD (auto axes) outside the shard_map
    # where the installed jax supports partial-manual (compat falls back to
    # full-manual with replicated data/model on 0.4.x).
    batch_in = P(None, None, None, None)
    pipelined = compat.shard_map(
        inner, mesh=mesh,
        in_specs=(P("stage"), P("stage"), batch_in),
        out_specs=batch_in,
        manual_axes=frozenset({"stage"}),
    )
    return pipelined


def plan_forward(model, params, batch, mesh: Mesh, plan: ExecutionPlan):
    """End-to-end plan execution: embed (data-parallel) -> pipelined
    uneven stages -> head.  batch: {'tokens' | 'embeds': ...}."""
    from repro.models import layers as L
    cfg = model.cfg
    assert plan.num_groups == cfg.num_groups, (plan.num_groups,
                                               cfg.num_groups)
    if "embeds" in batch:
        x = batch["embeds"].astype(cfg.dtype)
    else:
        x = L.embed(params["embed"], batch["tokens"], cfg).astype(cfg.dtype)
    B, seq, d = x.shape
    M = plan.total_microbatches
    assert B % M == 0, (B, M)
    x_mb = x.reshape(M, B // M, seq, d)

    staged = plan_stage_params(params["stack"], plan)
    mask = jnp.asarray(plan.group_mask_matrix())
    runner = make_plan_runner(cfg, mesh, plan)
    y_mb = runner(staged, mask, x_mb)
    y = y_mb.reshape(B, seq, d)
    y = L.apply_norm(params["final_norm"], y, cfg)
    return L.logits_head(params.get("embed"), params.get("head"), y, cfg)


# ---------------------------------------------------------------------------
# legacy scalar API — thin shims over a uniform plan
# ---------------------------------------------------------------------------

def make_pipeline_runner(cfg: ModelConfig, mesh: Mesh, n_stages: int,
                         n_microbatches: int) -> Callable:
    """Legacy runner: pipelined(params_staged, x_mb) -> y_mb with equal
    (S, G/S, ...) stage slices — a uniform plan under the hood."""
    plan = uniform_plan(cfg.num_groups, n_stages, n_microbatches)
    runner = make_plan_runner(cfg, mesh, plan)
    mask = jnp.asarray(plan.group_mask_matrix())

    def pipelined(params_staged, x_mb):
        return runner(params_staged, mask, x_mb)
    return pipelined


def pipeline_forward(model, params, batch, mesh: Mesh, n_stages: int,
                     n_microbatches: int):
    """Legacy end-to-end forward: lowers to a uniform plan."""
    plan = uniform_plan(model.cfg.num_groups, n_stages, n_microbatches)
    return plan_forward(model, params, batch, mesh, plan)
