"""Deterministic synthetic token pipeline.

Stateless generation: batch ``i`` is a pure function of (seed, i), so a
restarted job resumes mid-stream without replaying — the data-side half of
checkpoint/restart fault tolerance.  Sharding-aware: each host materializes
only its slice of the global batch (``process_index``/``process_count``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _philox(seed: int, step: int, shape, modulo: int) -> np.ndarray:
    """Cheap counter-based generator (splitmix-style) — stateless."""
    n = int(np.prod(shape))
    with np.errstate(over="ignore"):
        idx = np.arange(n, dtype=np.uint64) + np.uint64(step) * np.uint64(n)
        z = idx + np.uint64(seed) * np.uint64(0x9E3779B97F4A7C15)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        z = z ^ (z >> np.uint64(31))
    return (z % np.uint64(modulo)).astype(np.int32).reshape(shape)


@dataclass
class SyntheticLM:
    """Language-model batches: next-token targets over a synthetic stream."""
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0
    start_step: int = 0

    def host_batch(self) -> int:
        pc = jax.process_count()
        b = self.shape.global_batch
        assert b % pc == 0 or pc == 1, (b, pc)
        return max(b // pc, 1)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = self.start_step
        while True:
            yield self.batch_at(step)
            step += 1

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg, shp = self.cfg, self.shape
        b = self.host_batch()
        s = shp.seq_len
        base = self.seed + jax.process_index() * 1_000_003
        if cfg.family == "vision":
            emb = _philox(base, step, (b, s, cfg.d_model), 1000).astype(
                np.float32) / 500.0 - 1.0
            lbl = _philox(base + 7, step, (b,), cfg.vocab_size)
            return {"embeds": emb, "labels": lbl}
        if cfg.family == "audio":
            dec = max(s // 4, 8)
            emb = _philox(base, step, (b, s, cfg.d_model), 1000).astype(
                np.float32) / 500.0 - 1.0
            toks = _philox(base + 3, step, (b, dec + 1), cfg.vocab_size)
            return {"enc_embeds": emb, "dec_tokens": toks[:, :-1],
                    "labels": toks[:, 1:].copy()}
        if cfg.family == "vlm":
            emb = _philox(base, step, (b, s, cfg.d_model), 1000).astype(
                np.float32) / 500.0 - 1.0
            lbl = _philox(base + 3, step, (b, s), cfg.vocab_size)
            pos = np.broadcast_to(np.arange(s, dtype=np.int32)[None, None],
                                  (3, b, s)).copy()
            return {"embeds": emb, "labels": lbl, "positions": pos}
        toks = _philox(base, step, (b, s + 1), cfg.vocab_size)
        return {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}
