"""Distributed training launcher: pjit train loop with sharded params,
ZeRO-1 optimizer states, checkpoint/restart, and straggler-aware step
timing.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 10 \
        --seq 128 --batch 8            # local smoke (1 device)

On a real pod this is launched once per host (jax.distributed handles the
rest); the mesh comes from make_production_mesh().  Elastic restart: on
relaunch with a different device count the checkpoint is resharded onto the
new mesh (repro.checkpoint.restore with fresh shardings).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step
from repro.configs import REGISTRY, ShapeConfig, reduced
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.models import build_model
from repro.sharding import input_shardings_tree, param_shardings
from repro.training import AdamW, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--straggler-warn-ms", type=float, default=0.0,
                    help="warn when a step exceeds median by this margin")
    args = ap.parse_args(argv)

    cfg = REGISTRY[args.arch]
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    mesh = make_host_mesh()
    opt = AdamW(warmup_steps=10, total_steps=max(args.steps, 100))
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    data = SyntheticLM(cfg, shape)
    step_fn = make_train_step(model, opt, remat=True,
                              grad_accum=args.grad_accum)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    with use_mesh(mesh):
        params = model.init(jax.random.key(0))
        params = jax.device_put(params, param_shardings(params, mesh))
        opt_state = opt.init(params)
        start = 0
        if mgr and latest_step(args.ckpt_dir) is not None:
            restored, start = mgr.restore_latest(
                {"params": params, "opt": opt_state},
                shardings={"params": param_shardings(params, mesh),
                           "opt": None})
            params = restored["params"]
            o = restored["opt"]
            opt_state = type(opt_state)(step=jnp.asarray(o[0]), m=o[1],
                                        v=o[2]) \
                if isinstance(o, (list, tuple)) else o
            print(f"[train] resumed at step {start} "
                  f"(elastic reshard onto {mesh.devices.shape})")
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

        times = []
        for i in range(start, args.steps):
            t0 = time.perf_counter()
            batch = jax.device_put(
                jax.tree.map(jnp.asarray, data.batch_at(i)),
                input_shardings_tree(data.batch_at(i), mesh))
            params, opt_state, metrics = jitted(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            times.append(dt)
            # straggler mitigation hook: deterministic step budget — on a
            # fleet, a step exceeding the budget triggers microbatch
            # rebalancing / hot-spare promotion by the controller.
            if args.straggler_warn_ms and len(times) > 3:
                med = float(np.median(times[-10:]))
                if dt > med + args.straggler_warn_ms / 1e3:
                    print(f"[straggler] step {i} took {dt*1e3:.0f}ms "
                          f"(median {med*1e3:.0f}ms)")
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i}: loss={float(metrics['loss']):.4f} "
                      f"{dt*1e3:.0f}ms")
            if mgr and (i + 1) % args.ckpt_every == 0:
                mgr.save({"params": params, "opt": opt_state}, i + 1)
        if mgr:
            mgr.save({"params": params, "opt": opt_state}, args.steps)
            mgr.wait()
    print("[train] done")


if __name__ == "__main__":
    main()
