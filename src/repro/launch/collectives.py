"""Parse collective traffic out of lowered/compiled HLO text.

``cost_analysis()`` does not report collective bytes, so we walk the HLO:
sum the operand/result sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, weighting ops inside
``while`` bodies by the loop trip count (the scan over layer groups executes
its collectives num_groups times even though they appear once in the text).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^\s*%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_CALL_RE = re.compile(
    r"(?:to_apply|body|condition|branch_computations|called_computations|"
    r"calls)=\{?%?([\w\.\-,% ]+)\}?")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all shapes in an op line's result part."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("->" in stripped or
                                       stripped.startswith("ENTRY")):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            cur = m.group(1) if m else None
            if cur:
                comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _loop_trip_count(cond_lines: List[str]) -> int:
    """Best-effort: the largest integer constant compared in the condition."""
    best = 1
    for ln in cond_lines:
        if "constant(" in ln:
            for m in _TRIP_RE.finditer(ln):
                best = max(best, int(m.group(1)))
    return best


_DOT_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\bdot\(")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")


def _comp_multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """Trip-count multiplier per computation (while bodies × trip count)."""
    mult: Dict[str, float] = defaultdict(lambda: 1.0)
    changed, guard = True, 0
    while changed and guard < 6:
        changed = False
        guard += 1
        for name, lines in comps.items():
            base = mult[name]
            for ln in lines:
                m = _WHILE_RE.search(ln)
                if m:
                    cond, body = m.group(1), m.group(2)
                    trips = _loop_trip_count(comps.get(cond, []))
                    for target in (body, cond):
                        new = base * trips
                        if target in comps and mult[target] < new:
                            mult[target] = new
                            changed = True
                cm = _CALL_RE.search(ln)
                if cm:
                    for target in re.split(r"[,\s%]+", cm.group(1)):
                        if target in comps and mult[target] < base:
                            mult[target] = base
                            changed = True
    return mult


_DEF_RE = re.compile(r"^%?([\w\.\-]+)\s*=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*([a-z0-9]+)\[([0-9,]*)\]")


def _symbol_shapes(lines) -> Dict[str, List[int]]:
    """name -> dims for every value defined in a computation (post-opt HLO
    has no inline operand shapes, so dots need a symbol table)."""
    syms: Dict[str, List[int]] = {}
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            dims = [int(d) for d in m.group(3).split(",") if d.strip()]
            syms[m.group(1)] = dims
    return syms


def dot_flops(hlo: str) -> float:
    """Loop-aware matmul FLOPs: 2 × prod(result dims) × prod(contracting
    dims), each dot weighted by its computation's while-loop trip count.
    (XLA cost_analysis counts loop bodies once — this does not.)"""
    comps = _split_computations(hlo)
    mult = _comp_multipliers(comps)
    total = 0.0
    for name, lines in comps.items():
        f = mult[name]
        syms = None
        for ln in lines:
            if "dot(" not in ln:
                continue
            m = _DOT_RE.search(ln)
            if not m:
                continue
            res_dims = [int(d) for d in m.group(2).split(",") if d.strip()]
            res_n = 1
            for d in res_dims:
                res_n *= d
            # contracting dim sizes come from the lhs operand's shape:
            # inline if present (pre-0.5 HLO text: "dot(f32[8,8]{1,0} %x,
            # ...)" — NB the shape itself contains commas), else via the
            # computation's symbol table (post-opt HLO drops operand types).
            inside = ln.split("dot(", 1)[1]
            m_inline = re.match(r"\s*([a-z0-9]+)\[([0-9,]*)\]", inside)
            if m_inline and m_inline.group(1) in _DTYPE_BYTES:
                lhs_dims = [int(d) for d in m_inline.group(2).split(",")
                            if d.strip()]
            else:
                if syms is None:
                    syms = _symbol_shapes(lines)
                op = inside.split(",")[0].split(")")[0].strip().lstrip("%")
                lhs_dims = syms.get(op)
                if lhs_dims is None:
                    continue
            cm = _LHS_CONTRACT_RE.search(ln)
            contract = 1
            if cm:
                for idx in cm.group(1).split(","):
                    if idx.strip() and int(idx) < len(lhs_dims):
                        contract *= lhs_dims[int(idx)]
            total += f * 2.0 * res_n * contract
    return total


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Returns {collective_kind: bytes} (per device, trip-count weighted),
    plus '_total'."""
    comps = _split_computations(hlo)
    mult = _comp_multipliers(comps)

    out: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    for name, lines in comps.items():
        f = mult[name]
        for ln in lines:
            for kind in COLLECTIVES:
                # match op name at the '= <shape> kind(' position
                if re.search(rf"=\s*[a-z0-9\[\],\s()]*{kind}", ln) or \
                   re.search(rf"\b{kind}(?:-start|-done)?\(", ln):
                    # result shape(s) appear before the op name
                    lhs = ln.split("=")[1] if "=" in ln else ln
                    head = lhs.split(kind)[0]
                    b = _shape_bytes(head)
                    if b == 0:
                        b = _shape_bytes(ln.split("=")[0])
                    out[kind] += f * b
                    counts[kind] += 1
                    break
    out["_total"] = sum(out[k] for k in COLLECTIVES)
    out["_counts"] = counts  # type: ignore
    return out
