"""Serving launcher: batched inference through the per-slot
continuous-batching engine (the paper's application kind).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 8

Requests are admitted into slots of a persistent slot-indexed cache
(admission cost O(prompt), never O(active batch)); the printed stats are
the serving-side half of the SSR latency-throughput story.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import REGISTRY, reduced
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--eos", type=int, default=-1,
                    help="retire a slot on this token id (-1: disabled)")
    args = ap.parse_args(argv)

    cfg = reduced(REGISTRY[args.arch])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(model, params, slots=args.slots,
                        max_seq=args.max_seq)
    eos = None if args.eos < 0 else args.eos
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size, size=5).astype(np.int32)
        eng.submit(Request(uid, prompt, args.new_tokens, eos_token=eos))
    done = eng.run()
    wall = time.perf_counter() - t0
    st = eng.stats()
    print(f"[serve] {len(done)} requests, {st['gen_tokens']} tokens, "
          f"{st['gen_tokens']/wall:.1f} tok/s, "
          f"occupancy={st['slot_occupancy']:.2f}, "
          f"kernels={st['kernel_path']}")


if __name__ == "__main__":
    main()
