"""Serving launcher: batched inference through the continuous-batching
engine (the paper's application kind).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import REGISTRY, reduced
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = reduced(REGISTRY[args.arch])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(model, params, slots=args.slots,
                        max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size, size=5).astype(np.int32)
        eng.submit(Request(uid, prompt, args.new_tokens))
    done = eng.run()
    wall = time.perf_counter() - t0
    tok = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {tok} tokens, "
          f"{tok/wall:.1f} tok/s")


if __name__ == "__main__":
    main()
