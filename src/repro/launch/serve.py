"""Serving launcher: batched inference through the per-slot
continuous-batching engine (the paper's application kind).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 8

Requests are admitted into slots of a persistent slot-indexed cache
(admission cost O(prompt), never O(active batch)); the printed stats are
the serving-side half of the SSR latency-throughput story.

Plan-driven serving (``--strategy pipeline:S`` / ``--strategy hybrid:N``)
runs the engine on a lowered ``ServingPlan``: chunked prefill streams
through the plan's stage slices (``--chunk``) interleaved with decode,
and the plan's spatial width (``--replicas``) becomes independent
slot-partitioned decode replicas:

    python -m repro.launch.serve --arch yi-6b --strategy pipeline:2 \
        --replicas 2 --chunk 8
    python -m repro.launch.serve --arch yi-6b --strategy hybrid:2

``--paged [--page-size N --num-blocks M]`` swaps the dense slot caches
for the pool-backed paged layout (block tables + content-hash prefix
sharing, ``repro.cache``); the stats line then reports the block-pool
picture (peak blocks, reuse-hit rate, copy-on-writes, effective-slots
gain).  Composes with plan-driven serving (each replica owns a pool
partition).

``--prefix-cache`` / ``--no-prefix-cache`` (paged only; default on)
toggles cross-request prefix compute reuse: warm prefixes are looked up
in the registered block cache on admission and only the unmatched
suffix is prefilled; the stats line adds the prefix-hit picture
(prefill hit rate, reused tokens, registry block hits).

``--speculate K`` (``--no-speculate`` to force off) turns on speculative
decode: a prompt-lookup n-gram drafter proposes up to K tokens per slot
and one batched verify step scores them all; greedy verification keeps
every stream bit-identical to plain decode, so the stats line's
tokens-per-step and acceptance rate are pure latency wins.  Families
whose verify step is not decomposable (SSM mixers, local-window rings,
MoE) gate speculation off automatically.

``--adapt`` / ``--no-adapt`` (default off) turns on online Pareto
navigation: a re-plan controller watches the rolling traffic window and
swaps the engine between the monolithic point, the requested strategy's
plan, and its re-replicated variants at runtime — without dropping
requests, zero-copy on the paged path (slot migration is a block-table
handoff).  ``--slo-ttft S`` / ``--slo-tpot S`` set the controller's SLO
targets in seconds (both require ``--adapt`` and must be positive); the
stats line then adds the swap count and the final design point.

``--trace out.json`` records per-request lifecycle spans and per-stage /
per-replica engine timeline spans (``repro.obs``) and writes a
Chrome/Perfetto ``trace_event`` JSON to open in ui.perfetto.dev;
``--metrics-out out.prom`` writes Prometheus text-format metrics (TTFT /
TPOT histograms, utilization gauges) after the run.  Both are strictly
zero-overhead when not passed (see docs/observability.md).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import REGISTRY, ShapeConfig, reduced
from repro.models import build_model
from repro.serving import Request, ServingEngine


def _parse_strategy(strategy: str):
    """'pipeline:S' / 'hybrid:N' -> (kind, n); clear usage error otherwise."""
    kind, _, n = strategy.partition(":")
    if kind in ("pipeline", "hybrid") and n.isdigit() and int(n) >= 1:
        return kind, int(n)
    raise SystemExit(f"bad --strategy {strategy!r} "
                     f"(mono | pipeline:S | hybrid:N)")


def _build_serving_plan(cfg, strategy: str, slots: int, replicas: int,
                        chunk: int, max_seq: int):
    """Lower the requested strategy to a ServingPlan (None = monolithic)."""
    from repro.plan import lower, lower_serving, uniform_plan

    if strategy in ("mono", "sequential"):
        return None
    reps = replicas or min(2, slots)
    kind, n = _parse_strategy(strategy)
    if kind == "pipeline":
        plan = uniform_plan(cfg.num_groups, n, n_microbatches=reps)
    else:
        from repro.core import build_graph, evolutionary_search, ssr_dse
        from repro.core.assignment import contiguous_assignment
        n_acc = n
        g = build_graph(cfg, ShapeConfig("serve", max_seq, 8, "prefill"))
        res = evolutionary_search(g, 8, n_acc=n_acc, n_batches=2, n_pop=6,
                                  n_child=6, n_iter=3, seed=0)
        plan = lower(res.assignment, g, mesh_devices=8, n_microbatches=reps)
        if plan.n_stages < n_acc:
            # the EA legitimately collapses uniform stacks onto sequential;
            # serve the N-stage cut through the same customization pass
            _, _, assign = ssr_dse(
                g, contiguous_assignment(g, n_acc, 8).acc_of, 8,
                n_batches=n_acc)
            plan = lower(assign, g, mesh_devices=8, n_microbatches=reps)
    return lower_serving(plan, slots=slots, chunk=chunk)


def _adaptive_ladder(cfg, splan, slots: int, chunk: int):
    """Candidate design points for the re-plan controller: mono, the
    requested plan (or a default 2-stage cut when serving started mono),
    and its re-replicated spatial-width variants — one searched stage
    cut, several Pareto points."""
    from repro.plan import lower_serving, rereplicate_serving, uniform_plan
    if splan is None:
        n_stages = 2 if cfg.num_groups % 2 == 0 else 1
        base = lower_serving(
            uniform_plan(cfg.num_groups, n_stages,
                         n_microbatches=min(2, slots)),
            slots=slots, chunk=chunk)
    else:
        base = splan
    cands = [None, base]
    for r in sorted({1, min(2, slots), slots}):
        cand = rereplicate_serving(base, r)
        if all(cand != c for c in cands):
            cands.append(cand)
    return cands


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--eos", type=int, default=-1,
                    help="retire a slot on this token id (-1: disabled)")
    ap.add_argument("--strategy", default="mono",
                    help="mono | pipeline:S | hybrid:N (plan-driven)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="spatial decode replicas for plan-driven serving "
                         "(0: min(2, slots))")
    ap.add_argument("--chunk", type=int, default=8,
                    help="prefill chunk length for plan-driven serving")
    ap.add_argument("--paged", action="store_true",
                    help="pool-backed slot caches: paged global-attention "
                         "KV with prefix sharing (repro.cache)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV block with --paged")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="block-pool size with --paged "
                         "(0: slots * max_seq / page_size)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="with --paged: share registered prefix blocks "
                         "across requests and prefill only the suffix on a "
                         "warm prefix (default: on)")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="speculative decode: draft up to K tokens per slot "
                         "(prompt-lookup n-grams) and verify them in one "
                         "batched step; greedy verify keeps streams "
                         "bit-identical (0: disabled)")
    ap.add_argument("--no-speculate", action="store_const", const=0,
                    dest="speculate", help="force speculation off")
    ap.add_argument("--overlap", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="async overlapped runtime: dispatch decode step "
                         "N+1 before draining step N's tokens (bit-exact; "
                         "falls back to sync with effective --speculate)")
    ap.add_argument("--kv-dtype", default="fp", choices=("fp", "int8"),
                    help="with --paged: K/V block-pool storage dtype; int8 "
                         "adds per-row scales for >= 1.9x effective "
                         "capacity (bounded-error token streams)")
    ap.add_argument("--adapt", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="online Pareto navigation: re-plan the engine "
                         "between mono / the requested plan / its "
                         "re-replicated variants as traffic shifts "
                         "(zero-copy slot migration on --paged)")
    ap.add_argument("--slo-ttft", type=float, default=0.0, metavar="S",
                    help="with --adapt: target time-to-first-token in "
                         "seconds the controller penalizes against "
                         "(0: no TTFT SLO)")
    ap.add_argument("--slo-tpot", type=float, default=0.0, metavar="S",
                    help="with --adapt: target time-per-output-token in "
                         "seconds the controller penalizes against "
                         "(0: no TPOT SLO)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record engine + request lifecycle spans and "
                         "write a Chrome/Perfetto trace_event JSON here "
                         "(open in ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None, metavar="OUT.prom",
                    help="write Prometheus text-format metrics (TTFT/TPOT "
                         "histograms, utilization gauges) here after the "
                         "run")
    args = ap.parse_args(argv)

    if args.kv_dtype != "fp" and not args.paged:
        raise SystemExit("--kv-dtype int8 requires --paged: quantized K/V "
                         "blocks live in the paged block pool")

    if (args.slo_ttft or args.slo_tpot) and not args.adapt:
        raise SystemExit("--slo-ttft/--slo-tpot set SLO targets for the "
                         "adaptive re-plan controller: pass --adapt (a "
                         "static engine has no controller to penalize)")
    if args.slo_ttft < 0 or args.slo_tpot < 0:
        raise SystemExit("--slo-ttft/--slo-tpot are seconds and must be "
                         ">= 0 (0 disables that SLO term)")

    if args.prefix_cache and not args.paged:
        raise SystemExit("--prefix-cache requires --paged: prefix blocks "
                         "live in the paged block pool (dense slot caches "
                         "have no shareable blocks)")
    prefix_cache = True if args.prefix_cache is None else args.prefix_cache

    cfg = reduced(REGISTRY[args.arch])
    splan = _build_serving_plan(cfg, args.strategy, args.slots,
                                args.replicas, args.chunk, args.max_seq)
    if splan is not None:
        print(splan.describe())
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    adapt = None
    if args.adapt:
        from repro.serving import AdaptiveConfig
        adapt = AdaptiveConfig(
            plans=_adaptive_ladder(cfg, splan, args.slots, args.chunk),
            slo_ttft_s=args.slo_ttft, slo_tpot_s=args.slo_tpot)
    trace_cfg = None
    if args.trace:
        from repro.obs import TraceConfig
        trace_cfg = TraceConfig(path=args.trace)
    eng = ServingEngine(model, params, slots=args.slots,
                        max_seq=args.max_seq, plan=splan, paged=args.paged,
                        page_size=args.page_size,
                        num_blocks=args.num_blocks,
                        prefix_cache=prefix_cache,
                        speculate=args.speculate,
                        overlap=args.overlap, kv_dtype=args.kv_dtype,
                        adapt=adapt, trace=trace_cfg)
    if args.adapt:
        eng.warm_replans()                # compile candidates off the clock
        eng.reset_stats()
    eos = None if args.eos < 0 else args.eos
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size, size=5).astype(np.int32)
        eng.submit(Request(uid, prompt, args.new_tokens, eos_token=eos))
    done = eng.run()
    wall = time.perf_counter() - t0
    st = eng.stats()
    extra = ""
    if "plan_stages" in st:    # absent when --adapt ended on the mono point
        extra = (f", {st['plan_stages']} stages x "
                 f"{st['decode_replicas']} replicas (chunk "
                 f"{st['prefill_chunk']})")
    c = st["cache"]
    if c["layout"] == "paged":
        extra += (f", paged p{c['page_size']}: "
                  f"peak {c['peak_blocks_in_use']}/{c['num_blocks']} blocks"
                  f", reuse={c['reuse_hit_rate']:.2f}"
                  f", cow={c['cow_copies']}"
                  f", eff_slots_gain={c['effective_slots_gain']:.1f}x")
        if c["prefix_cache"]:
            extra += (f", prefix: hit_rate={c['prefill_hit_rate']:.2f}"
                      f" reused_tok={c['reused_prefill_tokens']}"
                      f" blocks_hit={c['prefix_hits']}")
        else:
            extra += ", prefix: off"
    if c["layout"] == "paged" and c.get("kv_dtype", "fp") != "fp":
        extra += (f", kv={c['kv_dtype']}"
                  f" capacity_x={c['kv_capacity_x']:.1f}")
    if args.overlap:
        extra += ", overlap=" + ("on" if eng._overlap else "sync(spec)")
    if st["spec_steps"]:
        extra += (f", spec k={args.speculate}: "
                  f"tok_per_step={st['tokens_per_step']:.2f}"
                  f" accept={st['acceptance_rate']:.2f}")
    elif args.speculate:
        extra += ", spec: gated off (family not verify-decomposable)"
    if args.adapt:
        extra += (f", adapt: replans={st['replans']}"
                  f" migrations={st['migrations']}"
                  f" (copies={st['migration_copies']})"
                  f" final={st['plan_label']}")
    print(f"[serve] {len(done)} requests, {st['gen_tokens']} tokens, "
          f"{st['gen_tokens']/wall:.1f} tok/s, "
          f"occupancy={st['slot_occupancy']:.2f}, "
          f"kernels={st['kernel_path']}{extra}")
    if args.trace:
        eng.write_trace(args.trace)
        print(f"[serve] trace: {args.trace} ({eng._tr.events} events"
              f", {eng._tr.dropped} dropped)")
    if args.metrics_out:
        from repro.obs import write_metrics
        write_metrics(eng.export_metrics(), args.metrics_out)
        print(f"[serve] metrics: {args.metrics_out}")


if __name__ == "__main__":
    main()
