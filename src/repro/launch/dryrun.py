import os
# MUST precede any jax import: jax locks the device count on first init.
# Append to (never clobber) a user-set XLA_FLAGS; respect an explicit
# device-count flag if the user already forced one.
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=512").strip()
del _flags

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape) cell, lower + compile the step
function (train_step / prefill / serve_step per shape kind) on the
single-pod (16,16) and multi-pod (2,16,16) production meshes with
ShapeDtypeStruct inputs (no allocation), then record:

  * memory_analysis  (bytes/device — proves it fits),
  * cost_analysis    (FLOPs / bytes for §Roofline),
  * collective bytes (parsed from HLO — §Roofline third term),
  * the three roofline terms + dominant bottleneck + MODEL_FLOPS ratio.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k \
      --strategy pipeline:4   # SSR spatial/hybrid executor dry-run
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, get_config, shapes_for
from repro.configs.base import LONG_500K, ModelConfig, ShapeConfig
from repro.core.graph import build_graph, model_flops
from repro.core.hw import TPU_V5E
from repro.launch.collectives import collective_bytes, dot_flops
from repro.launch.mesh import (make_pipeline_mesh, make_plan_mesh,
                               make_production_mesh, use_mesh)
from repro.models import build_model
from repro.sharding import input_shardings_tree, param_shardings
from repro.training import AdamW, make_train_step


def _tree_bytes_per_device(tree, shardings, n_dev: int) -> float:
    total = 0.0
    for leaf, shd in zip(jax.tree.leaves(tree), jax.tree.leaves(shardings)):
        size = np.prod(leaf.shape) * np.dtype(leaf.dtype).itemsize
        # shard count = product of mesh axis sizes used by the spec
        spec = getattr(shd, "spec", None)
        div = 1
        if spec is not None:
            mesh = shd.mesh
            for entry in spec:
                if entry is None:
                    continue
                names = entry if isinstance(entry, tuple) else (entry,)
                for nm in names:
                    div *= mesh.shape[nm]
        total += size / div
    return total


def _auto_grad_accum(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Pick microbatching so each microbatch carries ≤2 sequences per
    data shard (keeps remat'd activations within HBM)."""
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    per_shard = max(shape.global_batch // dp, 1)
    ga = max(per_shard // 2, 1)
    while ga > 1 and shape.global_batch % ga:   # ga must divide global batch
        ga -= 1
    return max(ga, 1)


def step_fn_and_args(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     strategy: str = "sequential", *, grad_accum: int = 0,
                     zero1: bool = True, expert_parallel: bool = False,
                     plan=None):
    """Build (fn, args, in_shardings) for the cell.  ``plan`` carries the
    ExecutionPlan for pipeline/hybrid strategies (built in run_cell so the
    mesh and the plan agree on stage count and widths)."""
    model = build_model(cfg)
    specs = model.input_specs(shape)
    params_sds = jax.eval_shape(model.init, jax.random.key(0))
    # FSDP auto-enable: TP-only would leave >4GB of weights per device
    pbytes = sum(np.prod(l.shape) * np.dtype(l.dtype).itemsize
                 for l in jax.tree.leaves(params_sds))
    tp = mesh.shape.get("model", 1)
    fsdp = (pbytes / tp) > 4e9
    pshard = param_shardings(params_sds, mesh, fsdp=fsdp,
                             expert_parallel=expert_parallel)

    if shape.kind == "train":
        opt = AdamW()
        opt_sds = jax.eval_shape(opt.init, params_sds)
        from jax.sharding import NamedSharding
        from repro.sharding import param_specs as _pspecs
        from repro.training import zero1_specs
        mspecs = _pspecs(params_sds, mesh, fsdp=fsdp,
                         expert_parallel=expert_parallel)
        if zero1:
            mspecs = zero1_specs(mspecs, params_sds, mesh)
        msh = jax.tree.map(lambda s: NamedSharding(mesh, s), mspecs,
                           is_leaf=lambda x: hasattr(x, "_normalized_spec")
                           or type(x).__name__ == "PartitionSpec")
        oshard = type(opt_sds)(step=NamedSharding(
            mesh, jax.sharding.PartitionSpec()), m=msh,
            v=jax.tree.map(lambda s: s, msh))
        bshard = input_shardings_tree(specs, mesh)
        if strategy.startswith(("pipeline", "hybrid")):
            from repro.pipeline import plan_forward
            assert plan is not None, strategy

            def fn(params, opt_state, batch):
                # pipelined loss (SSR spatial/hybrid execution via the
                # lowered ExecutionPlan)
                logits = plan_forward(model, params, batch, mesh, plan)
                labels = batch["labels"]
                lp = jax.nn.log_softmax(logits, axis=-1)
                nll = -jnp.take_along_axis(
                    lp, jnp.maximum(labels, 0)[..., None], -1)[..., 0]
                return jnp.mean(nll)
            return fn, (params_sds, opt_sds, specs), \
                (pshard, oshard, bshard), None
        ga = grad_accum or _auto_grad_accum(cfg, shape, mesh)
        step = make_train_step(model, opt, remat=True, grad_accum=ga)
        return step, (params_sds, opt_sds, specs), \
            (pshard, oshard, bshard), (pshard, oshard, None)

    if shape.kind == "prefill":
        bshard = input_shardings_tree(specs, mesh)

        def fn(params, batch):
            return model.prefill(params, batch, shape.seq_len)
        return fn, (params_sds, specs), (pshard, bshard), None

    # decode
    from repro.serving import make_serve_step
    serve = make_serve_step(model)
    cache = specs.pop("cache")
    tokens = specs.pop("tokens")
    cidx = specs.pop("cache_index")
    pos = specs.pop("positions", None)
    cshard = input_shardings_tree({"cache": cache}, mesh)["cache"]
    tshard = input_shardings_tree({"tokens": tokens}, mesh)["tokens"]
    from jax.sharding import NamedSharding, PartitionSpec as P
    ishard = NamedSharding(mesh, P())
    args = (params_sds, cache, tokens, cidx)
    shards = (pshard, cshard, tshard, ishard)
    if pos is not None:
        posshard = input_shardings_tree({"positions": pos}, mesh)["positions"]
        args = args + (pos,)
        shards = shards + (posshard,)
    return serve, args, shards, None


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             strategy: str = "sequential", with_text: bool = True,
             grad_accum: int = 0, expert_parallel: bool = False
             ) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    applicable = shape in shapes_for(cfg) or shape.name != "long_500k"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "pure full-attention arch: long_500k requires "
                          "sub-quadratic attention (DESIGN.md §skips)"}

    plan = None
    if strategy.startswith("pipeline"):
        # uniform plan on the legacy pipeline mesh (the shim contract)
        n_stages = int(strategy.split(":")[1])
        from repro.plan import uniform_plan
        plan = uniform_plan(cfg.num_groups, n_stages,
                            n_microbatches=n_stages)
        mesh = make_pipeline_mesh(n_stages, multi_pod=multi_pod)
    elif strategy.startswith("hybrid"):
        # EA-searched heterogeneous plan lowered onto the pod
        n_acc = int(strategy.split(":")[1])
        total = 512 if multi_pod else 256
        from repro.core import evolutionary_search, ssr_dse
        from repro.core.assignment import contiguous_assignment
        from repro.plan import lower
        g = build_graph(cfg, shape)
        res = evolutionary_search(g, total, n_acc=n_acc, n_batches=n_acc,
                                  n_pop=6, n_child=6, n_iter=3, seed=0)
        plan = lower(res.assignment, g, mesh_devices=total)
        if plan.n_stages < n_acc:
            # the EA legitimately collapses uniform dense stacks onto
            # sequential; the dry-run's job is to exercise the N-stage
            # executor, so fall back to the FLOPs-balanced contiguous
            # N-way cut through the same DSE customization pass
            _, _, assign = ssr_dse(
                g, contiguous_assignment(g, n_acc, total).acc_of, total,
                n_batches=n_acc)
            plan = lower(assign, g, mesh_devices=total)
        mesh = make_plan_mesh(plan, devices=jax.devices()[:total])
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size

    t0 = time.perf_counter()
    fn, args, in_sh, out_sh = step_fn_and_args(
        cfg, shape, mesh, strategy, grad_accum=grad_accum,
        expert_parallel=expert_parallel, plan=plan)
    with use_mesh(mesh):
        if out_sh is not None:
            # train step: donate params+opt (buffer reuse — ZeRO-1 friendly)
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=(0, 1))
        else:
            jitted = jax.jit(fn, in_shardings=in_sh)
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    # --- analyses ---
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and
                k in ("flops", "bytes accessed", "transcendentals",
                      "optimal_seconds")}
    except Exception as e:  # pragma: no cover
        cost = {"error": str(e)}

    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {a: float(getattr(ma, a)) for a in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, a)}
    except Exception:
        pass

    arg_bytes_dev = _tree_bytes_per_device(
        jax.tree.leaves((args,)), jax.tree.leaves((in_sh,)), n_dev) \
        if in_sh is not None else 0.0

    coll = {}
    dflops = 0.0
    if with_text:
        try:
            txt = compiled.as_text()
            coll = collective_bytes(txt)
            coll.pop("_counts", None)
            dflops = dot_flops(txt)     # loop-aware (cost_analysis counts
            del txt                     # while bodies once; this does not)
        except Exception as e:  # pragma: no cover
            coll = {"error": str(e)}

    # --- roofline terms (per device) ---
    hw = TPU_V5E
    flops_static = cost.get("flops", 0.0)
    flops_dev = dflops or flops_static
    bytes_dev = cost.get("bytes accessed", 0.0)
    # scale the (loop-body-once) byte count by the same loop expansion the
    # dot FLOPs saw — documented approximation for the §Roofline memory term.
    byte_scale = (flops_dev / flops_static) if flops_static else 1.0
    bytes_scaled = bytes_dev * max(byte_scale, 1.0)
    coll_dev = coll.get("_total", 0.0)
    t_comp = flops_dev / hw.peak_flops
    t_mem = bytes_scaled / hw.hbm_bw
    t_coll = coll_dev / (hw.ici_links_per_axis * hw.ici_bw)
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_total = flops_dev * n_dev
    ratio = mf / hlo_total if hlo_total else 0.0

    return {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "strategy": strategy, "devices": n_dev,
        "plan": plan.describe() if plan is not None else None,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_analysis": cost, "memory_analysis": mem,
        "argument_bytes_per_device": arg_bytes_dev,
        "collective_bytes": coll,
        "roofline": {**terms, "dominant": dominant,
                     "model_flops": mf,
                     "hlo_flops_per_dev_static": flops_static,
                     "hlo_flops_per_dev": flops_dev,
                     "hlo_bytes_per_dev_scaled": bytes_scaled,
                     "hlo_flops_total": hlo_total,
                     "model_to_hlo_ratio": ratio},
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="sequential")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-text", action="store_true",
                    help="skip HLO text parsing (faster)")
    ap.add_argument("--grad-accum", type=int, default=0,
                    help="0 = auto (≤2 sequences per microbatch per shard)")
    ap.add_argument("--ep", action="store_true",
                    help="expert-parallel MoE sharding (experts over model)")
    ap.add_argument("--tag", default="", help="suffix for the output json")
    args = ap.parse_args(argv)

    if args.all:
        os.makedirs(args.out, exist_ok=True)
        failures = []
        for arch in ARCHS:
            for shp in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
                tag = "mp" if args.multi_pod else "sp"
                out_file = os.path.join(args.out,
                                        f"{arch}__{shp}__{tag}.json")
                if os.path.exists(out_file):
                    print(f"[skip existing] {out_file}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shp, "--out", args.out]
                if args.multi_pod:
                    cmd.append("--multi-pod")
                if args.no_text:
                    cmd.append("--no-text")
                print(f"[run] {arch} x {shp} ({tag})", flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True)
                if r.returncode != 0:
                    failures.append((arch, shp))
                    print(r.stdout[-2000:])
                    print(r.stderr[-4000:])
        print(f"done; failures: {failures}")
        sys.exit(1 if failures else 0)

    res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   strategy=args.strategy, with_text=not args.no_text,
                   grad_accum=args.grad_accum, expert_parallel=args.ep)
    print(json.dumps(res, indent=2, default=str))
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        tag = "mp" if args.multi_pod else "sp"
        sfx = "" if args.strategy == "sequential" else \
            f"__{args.strategy.replace(':', '')}"
        if args.tag:
            sfx += f"__{args.tag}"
        path = os.path.join(
            args.out, f"{args.arch}__{args.shape}__{tag}{sfx}.json")
        with open(path, "w") as f:
            json.dump(res, f, indent=2, default=str)


if __name__ == "__main__":
    main()
