"""Production meshes.

``make_production_mesh`` builds the assignment's target meshes:
  * single-pod: (16, 16) over ("data", "model") — 256 chips;
  * multi-pod:  (2, 16, 16) over ("pod", "data", "model") — 512 chips.

``make_pipeline_mesh`` carves an SSR ``stage`` axis out of the data axis for
the spatial/hybrid executor.

These are FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before first jax init while tests/benches see 1 device.

All mesh construction and ambient-mesh context handling routes through
``repro.backend.compat`` — the jax 0.4.x/0.7.x API split lives there.
"""
from __future__ import annotations

import jax

from repro.backend import compat
from repro.backend.compat import use_mesh  # re-export (public API)


def _make_mesh(shape, axes):
    # Auto axis types (where the installed jax has them): we rely on GSPMD
    # propagation + constraints.
    return compat.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_pipeline_mesh(n_stages: int, *, model: int = 16, total: int = 256,
                       multi_pod: bool = False):
    """SSR spatial/hybrid mesh: ("stage", "data", "model").  The stage axis
    is carved out of the data axis of the production mesh."""
    if multi_pod:
        total = 512
    data = total // (n_stages * model)
    if data < 1 or n_stages * data * model != total:
        raise ValueError(
            f"make_pipeline_mesh: n_stages={n_stages} x model={model} does "
            f"not evenly divide the {total}-device budget (would silently "
            f"mis-factor the mesh); pick n_stages from the divisors of "
            f"{total // model}")
    return _make_mesh((n_stages, data, model), ("stage", "data", "model"))


def make_plan_mesh(plan, devices=None):
    """Stage-major mesh for an ``ExecutionPlan``: ("stage", data, model)
    with a *uniform* slot width per stage (a rectangular device mesh cannot
    give stages different widths — the plan records the replicate-padding
    waste of stages that asked for less, see ``StagePlan.replica_waste``).

    The slot width is ``len(devices) // n_stages`` capped at the plan's own
    ``stage_width``; leftover devices (budget not divisible by the stage
    count) are simply left out of the mesh.  The (data, model) split is the
    plan's common factorization (gcd of the stage tp's)."""
    import numpy as np

    devs = list(devices if devices is not None else jax.devices())
    S = plan.n_stages
    if len(devs) < S:
        raise ValueError(
            f"make_plan_mesh: the plan has {S} stages but only "
            f"{len(devs)} device(s) are available — every stage needs its "
            f"own mesh slot")
    width = max(len(devs) // S, 1)
    if plan.stage_width and plan.stage_width <= width:
        width = plan.stage_width
    assert S * width <= len(devs), (S, width, len(devs))
    data, model = plan.mesh_factors(width)
    arr = np.array(devs[:S * data * model]).reshape(S, data, model)
    return compat.make_mesh_on(arr, ("stage", "data", "model"))


def make_host_mesh(axes=("data", "model")):
    """Whatever devices exist locally, as a small mesh (tests/examples)."""
    n = len(jax.devices())
    if len(axes) == 1:
        return _make_mesh((n,), axes)
    # put everything on data
    return _make_mesh((n, 1), axes)
