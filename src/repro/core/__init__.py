"""SSR core: the paper's contribution — layer-graph IR, analytical TPU cost
model, Layer→Acc evolutionary search (Alg. 1), inter-acc-aware
customization (Alg. 2), pipeline scheduling, Pareto exploration."""
from repro.core.assignment import (Assignment, ScheduleResult,
                                   contiguous_assignment,
                                   sequential_assignment, simulate,
                                   spatial_assignment)
from repro.core.costmodel import AccConfig, Features, node_time, stage_time
from repro.core.ea import (DSEResult, evolutionary_search, exhaustive_search,
                           ssr_dse)
from repro.core.graph import Graph, MatmulShape, Node, build_graph, model_flops
from repro.core.hw import CHIPS, TPU_V5E, VCK190, mxu_efficiency
from repro.core.pareto import (DesignPoint, best_under_latency, pareto_front,
                               strategy_points)
