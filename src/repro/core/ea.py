"""SSR evolutionary Layer→Acc search — faithful port of the paper's
Algorithm 1 (population, single-point crossover, mutation, elitist update),
with the SSR_DSE inner pass = greedy schedule + chip allocation +
Algorithm-2 customization.

Also provides the exhaustive-search baseline used for the Fig.-10
search-efficiency comparison.
"""
from __future__ import annotations

import itertools
import math
import random
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.assignment import (Assignment, ScheduleResult,
                                   allocate_chips, simulate)
from repro.core.costmodel import Features
from repro.core.customize import customize_accs
from repro.core.graph import Graph
from repro.core.hw import Chip, TPU_V5E


@dataclass
class DSEResult:
    assignment: Assignment
    latency: float
    throughput: float           # TOPS-equivalent (1e12 MM FLOP/s)
    evaluations: int = 0
    wall_time_s: float = 0.0
    history: List[Tuple[int, float]] = field(default_factory=list)


def ssr_dse(graph: Graph, acc_of: Sequence[int], total_chips: int,
            n_batches: int, *, hw: Chip = TPU_V5E,
            feats: Features = Features()) -> Tuple[float, float, Assignment]:
    """One SSR_DSE pass (Algorithm 1 lines 27-37): greedy schedule +
    resource pre-allocation + Algorithm-2 customization + evaluation.
    Returns (latency, throughput_tops, assignment)."""
    n_acc = max(acc_of) + 1
    chip_alloc = [a.chips for a in
                  allocate_chips(graph, acc_of, n_acc, total_chips)]
    frac = 1.0 / n_batches
    accs = customize_accs(graph, acc_of, chip_alloc, hw=hw, feats=feats,
                          batch_frac=frac)
    assign = Assignment(tuple(acc_of), tuple(accs))
    res = simulate(graph, assign, n_batches, hw=hw, feats=feats)
    # "latency" here = the paper's metric: completion time of the whole
    # submitted batch workload (Table 5/6/7 report batch latency).
    return res.makespan, res.throughput_tops(), assign


def _random_assignment(rng: random.Random, n_nodes: int, n_acc: int
                       ) -> Tuple[int, ...]:
    """Random *contiguous-ish* partition: sorted cut points — keeps the
    population in the feasible region (chain deps make scattered
    assignments strictly worse; the paper's EA also seeds structured maps)."""
    if n_acc == 1:
        return tuple([0] * n_nodes)
    cuts = sorted(rng.sample(range(1, n_nodes), min(n_acc - 1, n_nodes - 1)))
    out, acc = [], 0
    for i in range(n_nodes):
        while acc < len(cuts) and i >= cuts[acc]:
            acc += 1
        out.append(acc)
    return tuple(out)


def _sp_crossover(rng: random.Random, p1, p2):
    pt = rng.randrange(1, len(p1))
    c1 = _renumber(p1[:pt] + p2[pt:])
    c2 = _renumber(p2[:pt] + p1[pt:])
    return c1, c2


def _mutate(rng: random.Random, g, n_acc: int):
    g = list(g)
    i = rng.randrange(len(g))
    j = rng.randrange(len(g))
    g[i], g[j] = g[j], g[i]
    return _renumber(tuple(g))


def _renumber(g: Tuple[int, ...]) -> Tuple[int, ...]:
    """Canonicalize acc ids to 0..k-1 in first-appearance order."""
    seen = {}
    out = []
    for a in g:
        if a not in seen:
            seen[a] = len(seen)
        out.append(seen[a])
    return tuple(out)


def evolutionary_search(graph: Graph, total_chips: int, *,
                        lat_cons: float = math.inf, n_acc: int = 4,
                        n_batches: int = 4, n_pop: int = 16,
                        n_child: int = 16, n_iter: int = 12,
                        seed: int = 0, hw: Chip = TPU_V5E,
                        feats: Features = Features()) -> DSEResult:
    """Algorithm 1.  Maximizes throughput s.t. latency <= lat_cons."""
    rng = random.Random(seed)
    n_nodes = len(graph.nodes)
    t0 = time.perf_counter()
    evals = 0
    history: List[Tuple[int, float]] = []

    def fitness(g):
        nonlocal evals
        evals += 1
        lat, thr, assign = ssr_dse(graph, g, total_chips, n_batches,
                                   hw=hw, feats=feats)
        ok = lat <= lat_cons
        return (thr if ok else -1.0 / max(thr, 1e-9)), lat, thr, assign

    # seed with the pure-sequential and fully-spatial genomes — the paper's
    # hybrid space explicitly includes both endpoints (Table 6 note).
    pop = [tuple([0] * n_nodes),
           _random_assignment(rng, n_nodes, n_acc)]
    pop += [_random_assignment(rng, n_nodes, rng.randint(1, n_acc))
            for _ in range(max(n_pop - 2, 0))]
    scored = [(fitness(g), g) for g in pop]
    best = None
    for (fit, lat, thr, assign), g in scored:
        if best is None or fit > best[0]:
            best = (fit, lat, thr, assign)
        history.append((evals, best[2] if best[1] <= lat_cons else 0.0))

    for _ in range(n_iter):
        # selection: fitness-proportional over top half
        ranked = sorted(scored, key=lambda x: -x[0][0])
        parents = [g for _, g in ranked[:max(2, n_pop // 2)]]
        children = []
        for _ in range(n_child // 2):
            p1, p2 = rng.sample(parents, 2)
            c1, c2 = _sp_crossover(rng, p1, p2)
            children += [c1, c2]
        children = [_mutate(rng, c, n_acc) if rng.random() < 0.4 else c
                    for c in children]
        child_scored = [(fitness(c), c) for c in children]
        for (fit, lat, thr, assign), g in child_scored:
            if lat <= lat_cons and (best is None or thr > best[2]
                                    or best[1] > lat_cons):
                best = (fit, lat, thr, assign)
            history.append((evals, best[2] if best[1] <= lat_cons else 0.0))
        # elitist population update
        scored = sorted(scored + child_scored, key=lambda x: -x[0][0])[:n_pop]

    fit, lat, thr, assign = best
    return DSEResult(assignment=assign, latency=lat, throughput=thr,
                     evaluations=evals,
                     wall_time_s=time.perf_counter() - t0, history=history)


def exhaustive_search(graph: Graph, total_chips: int, *,
                      lat_cons: float = math.inf, n_acc: int = 4,
                      n_batches: int = 4, max_evals: int = 20000,
                      hw: Chip = TPU_V5E, feats: Features = Features()
                      ) -> DSEResult:
    """Baseline: enumerate contiguous partitions into ≤ n_acc stages
    (the paper's exhaustive baseline post-verifies comm overhead; ours
    evaluates the same space without the inter-acc-aware pruning)."""
    n_nodes = len(graph.nodes)
    t0 = time.perf_counter()
    evals = 0
    best: Optional[Tuple[float, float, Assignment]] = None
    history: List[Tuple[int, float]] = []
    no_prune = Features(onchip_forwarding=feats.onchip_forwarding,
                        fine_grained_pipeline=feats.fine_grained_pipeline,
                        inter_acc_aware=False)

    for k in range(1, n_acc + 1):
        for cuts in itertools.combinations(range(1, n_nodes), k - 1):
            if evals >= max_evals:
                break
            g, acc = [], 0
            cl = list(cuts)
            for i in range(n_nodes):
                while acc < len(cl) and i >= cl[acc]:
                    acc += 1
                g.append(acc)
            lat, thr, assign = ssr_dse(graph, tuple(g), total_chips,
                                       n_batches, hw=hw, feats=no_prune)
            evals += 1
            if lat <= lat_cons and (best is None or thr > best[1]):
                best = (lat, thr, assign)
            history.append((evals, best[1] if best else 0.0))
    if best is None:
        lat, thr, assign = ssr_dse(graph, tuple([0] * n_nodes), total_chips,
                                   n_batches, hw=hw, feats=no_prune)
        best = (lat, thr, assign)
    lat, thr, assign = best
    return DSEResult(assignment=assign, latency=lat, throughput=thr,
                     evaluations=evals,
                     wall_time_s=time.perf_counter() - t0, history=history)
