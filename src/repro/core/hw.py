"""Hardware model: TPU v5e constants (the assignment's target) plus the
paper's platforms for the analytical-model cross-checks (§6 Q1).

All rates are per chip.  ICI_BW is per-link per-direction; a chip on a 2-D
torus has 4 links (2 per mesh axis)."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    name: str
    peak_flops: float          # bf16 (or the platform's serving dtype) FLOP/s
    hbm_bw: float              # bytes/s
    ici_bw: float              # bytes/s per link per direction
    ici_links_per_axis: int    # links per mesh axis (torus: 2)
    hbm_bytes: float
    vmem_bytes: float
    # VPU (vector unit) throughput for nonlinear ops, FLOP/s.
    vpu_flops: float
    # True when inference weights live in on-chip SRAM (the paper's
    # HMM-type0 weight pinning on AIE local memory): steady-state inference
    # pays no off-chip weight traffic.  TPU weights live in HBM -> False.
    weights_resident: bool = False
    # Systolic/matrix-unit tile edge: matmul dims pad to multiples of this.
    # TPU MXU: 128.  AIE cores / FPGA tensor blocks work on ~32-wide tiles,
    # which is why shape mismatch hurts the TPU more (DESIGN.md §2).
    tile: int = 128
    # Achievable fraction of peak for perfectly-shaped matmuls (XLA on MXU
    # sustains ~0.95; CHARM reports ~0.70 for AIE MM kernels).
    max_eff: float = 0.95
    # True for spatial-dataflow platforms whose per-acc array config is
    # frozen at build time (ACAP bitstream): an acc hosting differently-
    # shaped layers runs every layer on a config sized for its largest —
    # the paper's monolithic-acc shape-mismatch penalty (10.9% util).
    # TPUs re-tile per XLA program: False.
    fixed_config: bool = False


TPU_V5E = Chip(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    ici_links_per_axis=2,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
    vpu_flops=4e12,
)

# Paper platforms, used only by the §6-Q1 cross-platform modeling benchmark.
VCK190 = Chip(
    name="vck190",
    peak_flops=102.4e12,       # INT8 AIE peak
    hbm_bw=25.6e9,             # DDR4
    ici_bw=12.5e9,             # 100Gb/s QSFP28 (multi-board, §6 Q2)
    ici_links_per_axis=1,
    hbm_bytes=8 * 1024**3,
    vmem_bytes=32 * 1024,      # AIE local memory per core (the paper's 32KB)
    vpu_flops=1.8e12,          # PL fabric nonlinear engines (Table 8)
)

STRATIX10_NX = Chip(
    name="stratix10-nx",
    peak_flops=143e12,         # INT8 tensor blocks
    hbm_bw=512e9,
    ici_bw=12.5e9,
    ici_links_per_axis=1,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=16 * 1024**2,   # 16MB on-chip
    vpu_flops=2e12,
)

A10G = Chip(
    name="a10g",
    peak_flops=140e12,         # INT8 tensor cores
    hbm_bw=600e9,
    ici_bw=8e9,                # PCIe-class
    ici_links_per_axis=1,
    hbm_bytes=24 * 1024**3,
    vmem_bytes=6 * 1024**2,
    vpu_flops=35e12 / 2,       # CUDA-core FP32 path
)

CHIPS = {c.name: c for c in (TPU_V5E, VCK190, STRATIX10_NX, A10G)}

# MXU systolic tile edge: matmul dims are padded to multiples of this, which
# is where SSR's "shape mismatch ⇒ low utilization" shows up on TPU.
MXU_TILE = 128
# Empirical ceiling on achievable matmul efficiency (rooflines are not 100%).
MAX_MXU_EFF = 0.95


def mxu_efficiency(m: int, k: int, n: int, tile: int = MXU_TILE,
                   ceiling: float = MAX_MXU_EFF) -> float:
    """Fraction of matrix-unit peak achievable for an (m,k,n) matmul:
    padding waste on each dim (the TPU analogue of the paper's Eq.2 `Eff`)."""
    def frac(d):
        if d <= 0:
            return 1.0
        import math
        return d / (tile * math.ceil(d / tile))
    return ceiling * frac(m) * frac(k) * frac(n)
