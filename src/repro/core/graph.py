"""Layer-graph IR for SSR scheduling.

The paper's Layer→Acc scheduler operates on the application graph (Fig. 4/5).
We build that graph from (ModelConfig × ShapeConfig): one node per
transformer block (plus embed/head), each annotated with

  * ``mm``          — list of MatmulShape (MXU work; the HMM part),
  * ``vpu_flops``   — nonlinear/elementwise work (the HCE part),
  * ``act_in/out``  — activation bytes crossing the node boundary
                      (the inter-acc communication the paper co-designs),
  * ``weight_bytes``— resident weights (HMM-type0 "pinning" budget),
  * ``state_bytes`` — KV-cache / recurrent state traffic per invocation,
  * ``deps``        — graph dependencies.

FLOP counts are *model FLOPs* for the whole (global_batch × seq) workload;
the cost model divides by the accelerator allocation.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Tuple

from repro.configs.base import BlockSpec, ModelConfig, ShapeConfig

BYTES = 2  # bf16


@dataclass(frozen=True)
class MatmulShape:
    m: int
    k: int
    n: int
    count: int = 1          # batched-matmul count (e.g. heads)
    tp_dim: str = "n"       # which dim tensor-parallelism splits: n|k|count
    dp_dim: str = "m"       # data parallelism always splits m (tokens)

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.k * self.n * self.count


@dataclass
class Node:
    idx: int
    name: str
    kind: str                       # embed|block|head
    mixer: str = ""                 # attn|mamba|... for blocks
    role: str = ""                  # op-granularity role (qkv|bmm_qk|...)
    mm: List[MatmulShape] = field(default_factory=list)
    vpu_flops: float = 0.0
    act_in: float = 0.0             # bytes
    act_out: float = 0.0
    weight_bytes: float = 0.0
    state_bytes: float = 0.0        # KV/recurrent state read+written
    deps: Tuple[int, ...] = ()

    @property
    def mm_flops(self) -> float:
        return sum(s.flops for s in self.mm)


@dataclass
class Graph:
    cfg: ModelConfig
    shape: ShapeConfig
    nodes: List[Node]
    train: bool = False

    @property
    def total_mm_flops(self) -> float:
        mult = 3.0 if self.train else 1.0        # fwd + bwd(2x)
        return mult * sum(n.mm_flops for n in self.nodes)

    @property
    def total_vpu_flops(self) -> float:
        mult = 2.0 if self.train else 1.0
        return mult * sum(n.vpu_flops for n in self.nodes)

    @property
    def total_weight_bytes(self) -> float:
        return sum(n.weight_bytes for n in self.nodes)


# ---------------------------------------------------------------------------
# per-block op accounting
# ---------------------------------------------------------------------------

def _attn_block(cfg: ModelConfig, blk: BlockSpec, B, S, kv_len, decode):
    """MatmulShapes + vpu flops for one attention block."""
    d, H, Hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    tok = B * S
    mm = [
        MatmulShape(tok, d, H * hd, tp_dim="n"),          # wq
        MatmulShape(tok, d, Hk * hd, tp_dim="n"),         # wk
        MatmulShape(tok, d, Hk * hd, tp_dim="n"),         # wv
        MatmulShape(tok, H * hd, d, tp_dim="k"),          # wo
    ]
    eff_kv = kv_len
    if blk.mixer == "attn_local":
        eff_kv = min(kv_len, cfg.window_size)
    causal_frac = 0.5 if (not decode and S == kv_len) else 1.0
    # scores + pv bmm (per query-head)
    mm.append(MatmulShape(S * causal_frac, hd, eff_kv, count=B * H,
                          tp_dim="count"))
    mm.append(MatmulShape(S * causal_frac, eff_kv, hd, count=B * H,
                          tp_dim="count"))
    vpu = 5.0 * B * H * S * eff_kv * causal_frac        # softmax
    vpu += 8.0 * tok * d                                 # norms + residual
    vpu += 6.0 * tok * H * hd                            # rope
    w = (2 * d * H * hd + 2 * d * Hk * hd) * BYTES
    state = 2 * B * eff_kv * Hk * hd * BYTES if decode else 0
    return mm, vpu, w, state


def _ffn(cfg: ModelConfig, blk: BlockSpec, B, S):
    d = cfg.d_model
    tok = B * S
    mm, vpu, w = [], 0.0, 0.0
    if blk.ffn == "dense":
        ff = cfg.d_ff
        n_in = 2 if cfg.gated_mlp else 1
        for _ in range(n_in):
            mm.append(MatmulShape(tok, d, ff, tp_dim="n"))
        mm.append(MatmulShape(tok, ff, d, tp_dim="k"))
        vpu += 4.0 * tok * ff
        w += (n_in + 1) * d * ff * BYTES
    elif blk.ffn == "moe":
        moe = cfg.moe
        e, k, ff = moe.num_experts, moe.experts_per_token, moe.expert_d_ff
        cf = moe.capacity_factor
        mm.append(MatmulShape(tok, d, e, tp_dim="n"))               # router
        routed_tok = int(tok * k * cf)
        per_exp = max(routed_tok // e, 1)
        for _ in range(2):
            mm.append(MatmulShape(per_exp, d, ff, count=e, tp_dim="n"))
        mm.append(MatmulShape(per_exp, ff, d, count=e, tp_dim="k"))
        vpu += 4.0 * routed_tok * ff + 10.0 * tok * e
        w += 3 * e * d * ff * BYTES
        if moe.num_shared_experts:
            sf = moe.shared_expert_d_ff
            mm.append(MatmulShape(tok, d, sf, tp_dim="n"))
            mm.append(MatmulShape(tok, d, sf, tp_dim="n"))
            mm.append(MatmulShape(tok, sf, d, tp_dim="k"))
            vpu += 4.0 * tok * sf
            w += 3 * d * sf * BYTES
    if blk.ffn != "none":
        vpu += 6.0 * tok * d                                        # norm+res
    return mm, vpu, w


def _mamba_block(cfg: ModelConfig, B, S):
    d = cfg.d_model
    di = cfg.ssm.expand * d
    n = cfg.ssm.d_state
    dtr = cfg.ssm.dt_rank or math.ceil(d / 16)
    tok = B * S
    mm = [
        MatmulShape(tok, d, 2 * di, tp_dim="n"),          # in_proj
        MatmulShape(tok, di, dtr + 2 * n, tp_dim="k"),    # x_proj
        MatmulShape(tok, dtr, di, tp_dim="n"),            # dt_proj
        MatmulShape(tok, di, d, tp_dim="k"),              # out_proj
    ]
    vpu = tok * di * (2 * cfg.ssm.d_conv + 10 * n + 10)   # conv+scan+gates
    w = (2 * d * di + di * (dtr + 2 * n) + dtr * di + di * d) * BYTES
    state = B * di * (n + cfg.ssm.d_conv) * 4             # fp32 state
    return mm, vpu, w, state


def _mlstm_block(cfg: ModelConfig, B, S, decode):
    d = cfg.d_model
    di = int(cfg.xlstm.mlstm_proj_factor * d)
    H = cfg.num_heads
    hd = di // H
    tok = B * S
    mm = [
        MatmulShape(tok, d, 2 * di, tp_dim="n"),
        MatmulShape(tok, di, di, tp_dim="n"),
        MatmulShape(tok, di, di, tp_dim="n"),
        MatmulShape(tok, di, di, tp_dim="n"),
        MatmulShape(tok, di, d, tp_dim="k"),
    ]
    # recurrence: rank-1 state updates, O(S * hd^2) per head — VPU/MXU mix;
    # count as MXU work in chunked form.
    mm.append(MatmulShape(S, hd, hd, count=2 * B * H, tp_dim="count"))
    vpu = tok * (10 * di + 8 * d)
    w = (2 * d * di + 3 * di * di + di * d) * BYTES
    state = B * H * hd * hd * 4 if decode else 0
    return mm, vpu, w, state


def _slstm_block(cfg: ModelConfig, B, S):
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    ff = int(cfg.xlstm.slstm_proj_factor * d)
    tok = B * S
    mm = [
        MatmulShape(tok, d, 4 * d, tp_dim="n"),                   # w_x
        MatmulShape(tok, hd, 4 * hd, count=H, tp_dim="count"),    # recurrent
        MatmulShape(tok, d, 2 * ff, tp_dim="n"),
        MatmulShape(tok, ff, d, tp_dim="k"),
    ]
    vpu = tok * 30 * d
    w = (4 * d * d + H * hd * 4 * hd + 3 * d * ff) * BYTES
    return mm, vpu, w, 4 * B * d * 4


# ---------------------------------------------------------------------------
# graph builders
# ---------------------------------------------------------------------------

def _block_node(cfg, blk, idx, name, B, S, kv_len, decode, deps):
    if blk.mixer.startswith("attn"):
        mm, vpu, w, st = _attn_block(cfg, blk, B, S, kv_len, decode)
    elif blk.mixer == "mamba":
        mm, vpu, w, st = _mamba_block(cfg, B, S)
    elif blk.mixer == "mlstm":
        mm, vpu, w, st = _mlstm_block(cfg, B, S, decode)
    elif blk.mixer == "slstm":
        mm, vpu, w, st = _slstm_block(cfg, B, S)
    else:
        raise ValueError(blk.mixer)
    mm2, vpu2, w2 = _ffn(cfg, blk, B, S)
    act = B * S * cfg.d_model * BYTES
    return Node(idx=idx, name=name, kind="block", mixer=blk.mixer,
                mm=mm + mm2, vpu_flops=vpu + vpu2, act_in=act, act_out=act,
                weight_bytes=w + w2, state_bytes=st, deps=deps)


def build_op_graph(cfg: ModelConfig, shape: ShapeConfig) -> Graph:
    """Op-granularity graph (paper Fig. 4): every attention block becomes
    qkv / bmm_qk / bmm_pv / wo / ffn_in / ffn_out nodes so each op can own a
    specialized accelerator reused across layers (paper Fig. 9).  Non-attn
    mixers stay whole (they are single fused ops on the target)."""
    B = shape.global_batch
    decode = shape.is_decode
    S = 1 if decode else shape.seq_len
    kv_len = shape.seq_len
    train = shape.kind == "train"
    d, H, Hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    tok = B * S
    act = tok * d * BYTES

    nodes: List[Node] = []
    nodes.append(Node(0, "embed", "embed", role="embed", act_out=act,
                      weight_bytes=0 if cfg.family in ("vlm", "vision")
                      else cfg.vocab_size * d * BYTES,
                      vpu_flops=tok * d, deps=()))
    prev = 0
    for li in range(cfg.num_layers):
        blk = cfg.block_pattern[li % len(cfg.block_pattern)]
        if blk.mixer.startswith("attn"):
            eff_kv = min(kv_len, cfg.window_size) \
                if blk.mixer == "attn_local" else kv_len
            cf = 0.5 if (not decode and S == kv_len) else 1.0
            qkv = Node(len(nodes), f"L{li}.qkv", "block", "attn", role="qkv",
                       mm=[MatmulShape(tok, d, (H + 2 * Hk) * hd, tp_dim="n")],
                       vpu_flops=8.0 * tok * d + 6 * tok * H * hd,
                       act_in=act, act_out=(H + 2 * Hk) * hd * tok * BYTES,
                       weight_bytes=d * (H + 2 * Hk) * hd * BYTES,
                       deps=(prev,))
            nodes.append(qkv)
            # one HMM-type1 acc computes QK^T -> softmax -> PV: the
            # scores matrix never leaves the accelerator (paper Fig. 9).
            bmm = Node(len(nodes), f"L{li}.attn_bmm", "block", "attn",
                       role="attn_bmm",
                       mm=[MatmulShape(S * cf, hd, eff_kv, count=B * H,
                                       tp_dim="count"),
                           MatmulShape(S * cf, eff_kv, hd, count=B * H,
                                       tp_dim="count")],
                       vpu_flops=5.0 * B * H * S * eff_kv * cf,
                       act_in=qkv.act_out, act_out=tok * H * hd * BYTES,
                       state_bytes=(2 * B * eff_kv * Hk * hd * BYTES
                                    if decode else 0),
                       deps=(qkv.idx,))
            nodes.append(bmm)
            wo = Node(len(nodes), f"L{li}.wo", "block", "attn", role="wo",
                      mm=[MatmulShape(tok, H * hd, d, tp_dim="k")],
                      act_in=bmm.act_out, act_out=act,
                      weight_bytes=H * hd * d * BYTES, deps=(bmm.idx,))
            nodes.append(wo)
            prev = wo.idx
        else:
            n = _block_node(cfg, BlockSpec(blk.mixer, "none"), len(nodes),
                            f"L{li}.{blk.mixer}", B, S, kv_len, decode,
                            (prev,))
            n.role = blk.mixer
            nodes.append(n)
            prev = n.idx
        if blk.ffn != "none":
            mm2, vpu2, w2 = _ffn(cfg, blk, B, S)
            if blk.ffn == "dense":
                ff = cfg.d_ff
                n_in = 2 if cfg.gated_mlp else 1
                fin = Node(len(nodes), f"L{li}.ffn_in", "block", blk.mixer,
                           role="ffn_in",
                           mm=[MatmulShape(tok, d, ff, tp_dim="n")] * n_in,
                           vpu_flops=4.0 * tok * ff + 3 * tok * d,
                           act_in=act, act_out=tok * ff * BYTES,
                           weight_bytes=n_in * d * ff * BYTES, deps=(prev,))
                nodes.append(fin)
                fout = Node(len(nodes), f"L{li}.ffn_out", "block", blk.mixer,
                            role="ffn_out",
                            mm=[MatmulShape(tok, ff, d, tp_dim="k")],
                            vpu_flops=3 * tok * d,
                            act_in=fin.act_out, act_out=act,
                            weight_bytes=ff * d * BYTES, deps=(fin.idx,))
                nodes.append(fout)
                prev = fout.idx
            else:
                n = Node(len(nodes), f"L{li}.moe", "block", blk.mixer,
                         role="moe", mm=mm2, vpu_flops=vpu2, act_in=act,
                         act_out=act, weight_bytes=w2, deps=(prev,))
                nodes.append(n)
                prev = n.idx
    head_tok = B if cfg.family == "vision" else tok
    nodes.append(Node(len(nodes), "head", "head", role="head",
                      mm=[MatmulShape(head_tok, d, cfg.vocab_size,
                                      tp_dim="n")],
                      act_in=act, vpu_flops=5 * head_tok * cfg.vocab_size,
                      weight_bytes=0 if cfg.tie_embeddings
                      else d * cfg.vocab_size * BYTES,
                      deps=(prev,)))
    return Graph(cfg, shape, nodes, train=train)


def build_graph(cfg: ModelConfig, shape: ShapeConfig,
                granularity: str = "block") -> Graph:
    """One node per block + embed + head; decode shapes build the per-step
    graph (S=1, kv_len=shape.seq_len).  granularity='op' splits attention
    blocks into per-op nodes (paper Fig. 4) — used by the paper-platform
    benchmarks."""
    if granularity == "op":
        return build_op_graph(cfg, shape)
    B = shape.global_batch
    decode = shape.is_decode
    S = 1 if decode else shape.seq_len
    kv_len = shape.seq_len
    train = shape.kind == "train"

    nodes: List[Node] = []
    act = B * S * cfg.d_model * BYTES

    if cfg.family == "audio" and not decode:
        # encoder stack over S frames, decoder over S//4 tokens w/ cross-attn
        from repro.models.model import AUDIO_DECODER_RATIO
        S_dec = max(S // AUDIO_DECODER_RATIO, 8)
        for i in range(cfg.encoder_layers):
            nodes.append(_block_node(cfg, cfg.block_pattern[0], len(nodes),
                                     f"enc{i}", B, S, S, False,
                                     (len(nodes) - 1,) if nodes else ()))
        enc_last = len(nodes) - 1
        emb = Node(len(nodes), "dec_embed", "embed",
                   act_out=B * S_dec * cfg.d_model * BYTES,
                   weight_bytes=cfg.vocab_size * cfg.d_model * BYTES,
                   vpu_flops=B * S_dec * cfg.d_model, deps=())
        nodes.append(emb)
        prev = emb.idx
        for i in range(cfg.num_layers):
            n = _block_node(cfg, cfg.block_pattern[0], len(nodes),
                            f"dec{i}", B, S_dec, S_dec, False,
                            (prev, enc_last))
            # cross-attention adds q/o + bmm versus encoder length S
            d, H, Hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
                cfg.head_dim
            n.mm = n.mm + [
                MatmulShape(B * S_dec, d, H * hd, tp_dim="n"),
                MatmulShape(B * S, d, 2 * Hk * hd, tp_dim="n"),
                MatmulShape(S_dec, hd, S, count=B * H, tp_dim="count"),
                MatmulShape(S_dec, S, hd, count=B * H, tp_dim="count"),
                MatmulShape(B * S_dec, H * hd, d, tp_dim="k"),
            ]
            nodes.append(n)
            prev = n.idx
        head = Node(len(nodes), "head", "head",
                    mm=[MatmulShape(B * S_dec, cfg.d_model, cfg.vocab_size,
                                    tp_dim="n")],
                    act_in=B * S_dec * cfg.d_model * BYTES,
                    weight_bytes=0,  # tied
                    vpu_flops=5 * B * S_dec * cfg.vocab_size, deps=(prev,))
        nodes.append(head)
        return Graph(cfg, shape, nodes, train=train)

    # ---- decoder-only (all other families) ----
    emb_w = cfg.vocab_size * cfg.d_model * BYTES
    if cfg.family in ("vlm", "vision"):
        emb_w = 0   # embeddings provided by the (stubbed) frontend
    nodes.append(Node(0, "embed", "embed", act_out=act, weight_bytes=emb_w,
                      vpu_flops=B * S * cfg.d_model, deps=()))
    for li in range(cfg.num_layers):
        blk = cfg.block_pattern[li % len(cfg.block_pattern)]
        nodes.append(_block_node(cfg, blk, len(nodes), f"L{li}:{blk.mixer}",
                                 B, S, kv_len, decode, (len(nodes) - 1,)))
    head_tok = B if cfg.family == "vision" else B * S
    head_w = 0 if cfg.tie_embeddings else cfg.d_model * cfg.vocab_size * BYTES
    nodes.append(Node(len(nodes), "head", "head",
                      mm=[MatmulShape(head_tok, cfg.d_model, cfg.vocab_size,
                                      tp_dim="n")],
                      act_in=act, weight_bytes=head_w,
                      vpu_flops=5 * head_tok * cfg.vocab_size,
                      deps=(len(nodes) - 1,)))
    return Graph(cfg, shape, nodes, train=train)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N_active·D for the roofline ratio (dense formula on
    active params; decode counts one token per batch item)."""
    g = build_graph(cfg, shape)
    # active params ≈ sum of per-layer matmul (k*n) sizes (weights actually
    # multiplied per token), so 6ND == 3 * sum(2*m*k*n) over weight matmuls.
    return g.total_mm_flops
