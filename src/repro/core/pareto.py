"""Latency-throughput Pareto exploration (paper Fig. 2 / Table 6).

Generates design points for the three strategies:
  * SSR-sequential — one monolithic acc, sweeping batch pipelining depth;
  * SSR-spatial    — one acc per layer(-group);
  * SSR-hybrid     — EA over layer→acc maps for several acc counts;
and computes the Pareto front (min latency, max throughput).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.core.assignment import (contiguous_assignment,
                                   sequential_assignment, simulate,
                                   spatial_assignment)
from repro.core.costmodel import Features
from repro.core.ea import evolutionary_search, ssr_dse
from repro.core.graph import Graph
from repro.core.hw import Chip, TPU_V5E


@dataclass(frozen=True)
class DesignPoint:
    strategy: str           # sequential | spatial | hybrid | serving-*
    n_acc: int
    n_batches: int
    latency: float
    throughput_tops: float
    detail: str = ""
    # provenance: "analytic" (cost-model simulate), "measured" (a lowered
    # ExecutionPlan executed + timed synthetically — repro.plan.validate),
    # or "served" (a ServingPlan driven by live Poisson request traffic
    # through the continuous-batching engine — benchmarks/serving.py)
    source: str = "analytic"


def strategy_points(graph: Graph, total_chips: int, *, hw: Chip = TPU_V5E,
                    feats: Features = Features(),
                    batches: Sequence[int] = (1, 2, 3, 4, 6, 8),
                    hybrid_accs: Sequence[int] = (2, 3, 4, 6),
                    ea_iters: int = 6, seed: int = 0) -> List[DesignPoint]:
    pts: List[DesignPoint] = []
    n_nodes = len(graph.nodes)

    for nb in batches:
        # sequential: one monolithic acc, nb pipelined (micro)batches
        seq = sequential_assignment(graph, total_chips)
        r = simulate(graph, seq, nb, hw=hw, feats=feats)
        pts.append(DesignPoint("sequential", 1, nb, r.makespan,
                               r.throughput_tops()))
        # spatial: one acc per layer group
        spa = spatial_assignment(graph, total_chips,
                                 max_accs=min(n_nodes, 16))
        r = simulate(graph, spa, nb, hw=hw, feats=feats)
        pts.append(DesignPoint("spatial", spa.n_acc, nb, r.makespan,
                               r.throughput_tops()))
        # hybrid: EA-optimized layer→acc map per acc count
        for na in hybrid_accs:
            if na >= n_nodes:
                continue
            res = evolutionary_search(
                graph, total_chips, n_acc=na, n_batches=nb,
                n_pop=8, n_child=8, n_iter=ea_iters, seed=seed, hw=hw,
                feats=feats)
            pts.append(DesignPoint("hybrid", na, nb, res.latency,
                                   res.throughput))
    return pts


def pareto_front(points: Sequence[DesignPoint]) -> List[DesignPoint]:
    """Non-dominated set: no other point has both lower latency and higher
    throughput."""
    out = []
    for p in points:
        dominated = any(
            (q.latency <= p.latency and q.throughput_tops > p.throughput_tops)
            or (q.latency < p.latency and
                q.throughput_tops >= p.throughput_tops)
            for q in points)
        if not dominated:
            out.append(p)
    return sorted(out, key=lambda p: p.latency)


def best_under_latency(points: Sequence[DesignPoint], lat_cons: float,
                       strategy: Optional[str] = None
                       ) -> Optional[DesignPoint]:
    """Table-6 query: max throughput subject to latency <= lat_cons."""
    cand = [p for p in points if p.latency <= lat_cons and
            (strategy is None or p.strategy == strategy or
             (strategy == "hybrid"))]  # hybrid includes seq+spatial designs
    if strategy == "hybrid":
        cand = [p for p in points if p.latency <= lat_cons]
    if not cand:
        return None
    return max(cand, key=lambda p: p.throughput_tops)
