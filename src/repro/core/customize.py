"""Acc-Customization DSE — faithful port of the paper's Algorithm 2.

For each accelerator (in Layer→Acc schedule order, so downstream accs see
their producers' configs), exhaustively search its config vector — here the
(dp, tp) factorization of its chip allocation plus the microbatch count —
subject to feasibility (Eq.-1 analog: HBM fit, dp ≤ batch, tp ≤ a shardable
width) and, when ``inter_acc_aware`` is on, the force-partition rule:
communicating accs must have divisible parallelism factors so inter-acc
forwarding needs no resharding (paper Fig. 8).
"""
from __future__ import annotations

import math
from dataclasses import replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.assignment import Assignment, simulate
from repro.core.costmodel import (AccConfig, Features, fits_hbm, stage_time)
from repro.core.graph import Graph, Node
from repro.core.hw import Chip, TPU_V5E


def _divisor_pairs(c: int) -> List[Tuple[int, int]]:
    out = []
    for dp in range(1, c + 1):
        if c % dp == 0:
            out.append((dp, c // dp))
    return out


def _max_tp(graph: Graph, node_ids: Sequence[int]) -> int:
    """TP cannot exceed the narrowest shardable width among the acc's
    layers (kv heads for attention, experts/ff for MoE, d_inner for SSM)."""
    cfg = graph.cfg
    width = cfg.d_model
    for i in node_ids:
        n = graph.nodes[i]
        if n.mixer.startswith("attn"):
            width = min(width, max(cfg.num_kv_heads, 1) * 16)
        # other mixers shard d_inner / d_ff: effectively wide enough
    return max(width, 1)


def _compatible(a: AccConfig, b: AccConfig) -> bool:
    return (a.dp % b.dp == 0 or b.dp % a.dp == 0) and \
           (a.tp % b.tp == 0 or b.tp % a.tp == 0)


def _comm_partners(graph: Graph, assign_of: Sequence[int]) -> Dict[int, set]:
    partners: Dict[int, set] = {}
    for n in graph.nodes:
        for d in n.deps:
            a, b = assign_of[d], assign_of[n.idx]
            if a != b:
                partners.setdefault(a, set()).add(b)
                partners.setdefault(b, set()).add(a)
    return partners


def customize_accs(graph: Graph, acc_of: Sequence[int],
                   chip_alloc: Sequence[int], *, hw: Chip = TPU_V5E,
                   feats: Features = Features(),
                   batch_frac: float = 1.0) -> List[AccConfig]:
    """Algorithm 2: per-acc exhaustive config search in schedule order with
    inter-acc-aware force-partition pruning."""
    n_acc = len(chip_alloc)
    order = sorted(range(n_acc),
                   key=lambda a: min((i for i, x in enumerate(acc_of)
                                      if x == a), default=1 << 30))
    partners = _comm_partners(graph, acc_of)
    chosen: Dict[int, AccConfig] = {}
    B = graph.shape.global_batch

    for a in order:
        node_ids = [i for i, x in enumerate(acc_of) if x == a]
        nodes = [graph.nodes[i] for i in node_ids]
        best: Optional[AccConfig] = None
        best_t = math.inf
        c = chip_alloc[a]
        for dp, tp in _divisor_pairs(c):
            if dp > max(1, B):
                continue
            cand = AccConfig(chips=c, dp=dp, tp=tp)
            if not fits_hbm(nodes, cand, graph, hw, batch_frac=batch_frac):
                continue
            if feats.inter_acc_aware:
                # force-partition: align with already-configured partners
                if any(p in chosen and not _compatible(cand, chosen[p])
                       for p in partners.get(a, ())):
                    continue
            t = stage_time(nodes, cand, graph, hw, batch_frac=batch_frac,
                           feats=feats)
            if t < best_t:
                best_t, best = t, cand
        if best is None:
            # infeasible under pruning: fall back to pure TP (always legal)
            best = AccConfig(chips=c, dp=1, tp=c)
        chosen[a] = best
    return [chosen[a] for a in range(n_acc)]


def count_design_points(chip_alloc: Sequence[int]) -> int:
    """Search-space size (for the Fig. 10 search-efficiency comparison)."""
    total = 1
    for c in chip_alloc:
        total *= len(_divisor_pairs(c))
    return total
