"""Analytical TPU cost model — the SSR Eq. 1 / Eq. 2 analogue.

The paper models each accelerator's cycle count from its AIE-array
parallelism (A,B,C) and workload (h1,w1,w2); feasibility comes from AIE,
PLIO, RAM, DSP budgets (Eq. 1); performance from Cycle = MNK/(ABC·MAC/Eff)
(Eq. 2).  On a TPU pod the per-accelerator resources are a *submesh*:

  config_vector := (chips c, data-par dp, tensor-par tp)   with dp·tp = c

and the per-layer time is a three-term roofline:

  t_compute  = local MM FLOPs / (peak · Eff(local matmul dims))
  t_hbm      = local bytes / HBM bw
  t_vpu      = local nonlinear FLOPs / VPU rate
  t_ici      = TP-collective bytes / ICI link bw

`Eff` is the MXU tile-padding efficiency — the exact TPU counterpart of the
paper's shape-mismatch observation.  The fine-grained-pipeline feature
(paper §4.3-②) decides whether t_vpu overlaps t_compute (max) or serializes
(sum); on-chip forwarding (§4.3-③/Fig 8) decides whether inter-acc transfers
ride the ICI or round-trip through host DRAM.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple

from repro.core.graph import Graph, MatmulShape, Node
from repro.core.hw import Chip, TPU_V5E, mxu_efficiency

HOST_BW = 16e9          # device<->host PCIe-class bytes/s (forwarding OFF)
COLL_EFF = 0.8          # achievable fraction of ICI peak for collectives


@dataclass(frozen=True)
class AccConfig:
    """One SSR accelerator = a submesh with a parallelism factorization."""
    chips: int
    dp: int
    tp: int

    def __post_init__(self):
        assert self.dp * self.tp == self.chips, (self.dp, self.tp, self.chips)


@dataclass(frozen=True)
class Features:
    """SSR optimization features (paper §5.2.6 step-by-step ablation)."""
    onchip_forwarding: bool = True     # (1) inter-acc via ICI not host
    fine_grained_pipeline: bool = True # (3) nonlinear overlapped with MM
    inter_acc_aware: bool = True       # force-partition co-design


def _shape_local(s: MatmulShape, dp: int, tp: int) -> Tuple[float, float, float, float]:
    """Local (m,k,n,count) after sharding a matmul over (dp, tp)."""
    m, k, n, cnt = s.m, s.k, s.n, s.count
    m = max(m / dp, 1.0)
    if s.tp_dim == "n":
        n = max(n / tp, 1.0)
    elif s.tp_dim == "k":
        k = max(k / tp, 1.0)
    elif s.tp_dim == "count":
        cnt = max(cnt / tp, 1.0)
    return m, k, n, cnt


def acc_ref_dims(nodes: List[Node], acc: AccConfig,
                 batch_frac: float = 1.0):
    """Per-acc frozen array configs (fixed_config platforms): per HMM type
    (type0 = MM, type1 = BMM, paper §4.3), the local (m, k, n) of the
    FLOPs-dominant matmul — the bitstream is sized for the dominant
    workload and every other layer runs padded to it (the paper's
    sequential-acc shape mismatch, §1: 10.9% utilization)."""
    best = {}          # HMM type -> (dims, flops)
    for node in nodes:
        for s in node.mm:
            lm, lk, ln, _ = _shape_local(s, acc.dp, acc.tp)
            if s.dp_dim == "m":
                lm = max(lm * batch_frac, 1.0)
            t = _hmm_type(s, lk, ln)
            if t not in best or s.flops > best[t][1]:
                best[t] = ((lm, lk, ln), s.flops)
    return {t: v[0] for t, v in best.items()}


def _hmm_type(s: MatmulShape, lk: float, ln: float) -> str:
    """HMM array type: MM (type0) vs the two BMM orientations (QK^T and PV
    have transposed aspect ratios; an HMM-type1 acc instantiates both)."""
    if s.count <= 1:
        return "mm"
    return "bmm_qk" if ln >= lk else "bmm_pv"


def node_time(node: Node, acc: AccConfig, hw: Chip = TPU_V5E, *,
              batch_frac: float = 1.0, train: bool = False,
              feats: Features = Features(), ref_dims=None
              ) -> Dict[str, float]:
    """Per-invocation time terms (seconds) for `node` on `acc`, processing
    ``batch_frac`` of the graph's global batch (microbatching).

    ref_dims: on fixed_config platforms every matmul pads to the acc's
    frozen array config instead of its own tile-padded shape."""
    mult = 3.0 if train else 1.0
    vmult = 2.0 if train else 1.0
    dp, tp, c = acc.dp, acc.tp, acc.chips

    t_compute = 0.0
    for s in node.mm:
        lm, lk, ln, lcnt = _shape_local(s, dp, tp)
        # microbatching scales the token dim (m)
        lm = max(lm * batch_frac, 1.0) if s.dp_dim == "m" else lm
        ref = None
        if ref_dims is not None and hw.fixed_config:
            ref = ref_dims.get(_hmm_type(s, lk, ln))
        if ref is not None:
            # paper §4.3: each acc has one HMM-type0 (MM) and one
            # HMM-type1 (BMM) array config — mismatch penalized per type.
            def _pad(d, r):
                return hw.tile * math.ceil(max(d, r) / hw.tile)
            eff = hw.max_eff * (lm / _pad(lm, ref[0])) \
                * (lk / _pad(lk, ref[1])) \
                * (ln / _pad(ln, ref[2]))
        else:
            eff = mxu_efficiency(int(round(lm)), int(round(lk)),
                                 int(round(ln)), tile=hw.tile,
                                 ceiling=hw.max_eff)
        local_flops = mult * s.flops * batch_frac / (dp * tp)
        t_compute += local_flops / (hw.peak_flops * max(eff, 1e-3))

    t_vpu = vmult * node.vpu_flops * batch_frac / c / hw.vpu_flops

    # HBM: weights read once per invocation per chip-shard; activations +
    # state streamed.  (Training re-reads weights in bwd: mult.)
    # weights_resident (paper HMM-type0 pinning): inference weights live in
    # on-chip SRAM -> zero steady-state off-chip weight traffic.
    if hw.weights_resident and not train:
        bytes_w = 0.0
    else:
        bytes_w = node.weight_bytes / c * (2.0 if train else 1.0)
    if hw.weights_resident and feats.onchip_forwarding and not train:
        # fully on-chip dataflow (paper premise: model fits on-chip):
        # activations stream BRAM->BRAM, never touching DDR.
        bytes_a = 0.0
    else:
        bytes_a = 2.0 * (node.act_in + node.act_out) * batch_frac / c
    bytes_s = node.state_bytes * batch_frac / (c if node.state_bytes else 1)
    t_hbm = (bytes_w + bytes_a + bytes_s) / hw.hbm_bw

    # On-chip forwarding OFF (CHARM-like baseline): every layer's
    # activations round-trip through off-chip DRAM, serially.
    t_dram_rt = 0.0
    if not feats.onchip_forwarding:
        t_dram_rt = 2.0 * (node.act_in + node.act_out) * batch_frac \
            / c / hw.hbm_bw

    # ICI: Megatron-style TP ⇒ one all-reduce of the activation after each
    # ROW-parallel (k-sharded) matmul; column-parallel outputs stay sharded.
    t_ici = 0.0
    if tp > 1 and node.kind == "block":
        n_ar = sum(1 for s in node.mm if s.tp_dim == "k")
        ar_bytes = n_ar * 2 * (tp - 1) / tp * node.act_out * batch_frac / dp
        t_ici = ar_bytes / (hw.ici_links_per_axis * hw.ici_bw * COLL_EFF)
        if train:
            t_ici *= 2
    if train and dp > 1 and node.weight_bytes:
        # gradient all-reduce over dp (amortized per microbatch invocation)
        gr = 2 * (dp - 1) / dp * node.weight_bytes / tp * batch_frac
        t_ici += gr / (hw.ici_links_per_axis * hw.ici_bw * COLL_EFF)

    if feats.fine_grained_pipeline:
        # nonlinear (VPU) and HBM streaming overlap the MXU pipeline
        total = max(t_compute, t_vpu, t_hbm) + t_ici + t_dram_rt
    else:
        total = t_compute + t_vpu + t_hbm + t_ici + t_dram_rt
    return {"compute": t_compute, "vpu": t_vpu, "hbm": t_hbm, "ici": t_ici,
            "dram_rt": t_dram_rt, "total": total}


def stage_time(nodes: List[Node], acc: AccConfig, graph: Graph,
               hw: Chip = TPU_V5E, *, batch_frac: float = 1.0,
               feats: Features = Features()) -> float:
    ref = acc_ref_dims(nodes, acc, batch_frac) if hw.fixed_config else None
    return sum(node_time(n, acc, hw, batch_frac=batch_frac,
                         train=graph.train, feats=feats,
                         ref_dims=ref)["total"]
               for n in nodes)


def stage_weight_bytes(nodes: List[Node]) -> float:
    return sum(n.weight_bytes + n.state_bytes for n in nodes)


def fits_hbm(nodes: List[Node], acc: AccConfig, graph: Graph,
             hw: Chip = TPU_V5E, *, batch_frac: float = 1.0) -> bool:
    w = sum(n.weight_bytes for n in nodes) / acc.chips
    st = sum(n.state_bytes for n in nodes) * batch_frac / acc.chips
    act = max((n.act_out for n in nodes), default=0.0) * batch_frac / acc.dp
    opt = 3.0 if graph.train else 0.0   # grads + adam m,v (bf16-ish model)
    return (w * (1 + opt) + st + 8 * act) <= 0.9 * hw.hbm_bytes


def transfer_time(prod_nodes: List[Node], prod: AccConfig, cons: AccConfig,
                  act_bytes: float, hw: Chip = TPU_V5E, *,
                  feats: Features = Features()) -> float:
    """Inter-accelerator activation transfer (the paper's Fig. 8).

    Compatible shardings (divisible dp/tp factors) → a collective-permute
    over ICI whose cost is the per-chip shard.  Incompatible → resharding
    all-to-all (the paper's "bank conflict" overhead, 3× traffic).  With
    on-chip forwarding disabled (CHARM-like baseline), everything round-trips
    through host DRAM."""
    if not feats.onchip_forwarding:
        return 2.0 * act_bytes / HOST_BW
    per_chip = act_bytes / max(min(prod.chips, cons.chips), 1)
    t = per_chip / (hw.ici_links_per_axis * hw.ici_bw * COLL_EFF)
    compatible = (prod.dp % cons.dp == 0 or cons.dp % prod.dp == 0) and \
                 (prod.tp % cons.tp == 0 or cons.tp % prod.tp == 0)
    if not compatible:
        t *= 3.0
    return t


def roofline_terms(graph: Graph, total_chips: int, hw: Chip = TPU_V5E
                   ) -> Dict[str, float]:
    """Whole-graph three-term roofline on a monolithic allocation (the
    §Roofline analytical cross-check)."""
    mm = graph.total_mm_flops
    t_compute = mm / (total_chips * hw.peak_flops)
    bytes_total = graph.total_weight_bytes + sum(
        4.0 * (n.act_in + n.act_out) + n.state_bytes for n in graph.nodes)
    t_hbm = bytes_total / (total_chips * hw.hbm_bw)
    return {"compute": t_compute, "hbm": t_hbm, "model_flops": mm}
