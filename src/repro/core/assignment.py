"""Layer→Acc assignment + the greedy pipeline scheduler (paper Fig. 5(c)).

An *assignment* maps every graph node to an accelerator id.  Given the
assignment, per-acc configs, and a number of batches, the scheduler
simulates the pipelined execution honoring graph dependencies and
accelerator occupancy — "assign a layer to the pipeline as soon as its
accelerator is available and its dependencies are resolved" — and returns
(single-batch latency, makespan, throughput).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.costmodel import (AccConfig, Features, node_time,
                                  transfer_time)
from repro.core.graph import Graph, Node
from repro.core.hw import Chip, TPU_V5E


@dataclass(frozen=True)
class Assignment:
    """node idx -> acc id; acc id -> AccConfig."""
    acc_of: Tuple[int, ...]
    accs: Tuple[AccConfig, ...]

    @property
    def n_acc(self) -> int:
        return len(self.accs)

    def nodes_of(self, acc_id: int) -> List[int]:
        return [i for i, a in enumerate(self.acc_of) if a == acc_id]


@dataclass
class ScheduleResult:
    latency: float          # first-batch end-to-end latency (s)
    makespan: float         # all-batch completion (s)
    throughput_flops: float # model MM FLOP/s sustained over makespan
    per_acc_busy: List[float] = field(default_factory=list)

    def throughput_tops(self) -> float:
        return self.throughput_flops / 1e12


def simulate(graph: Graph, assign: Assignment, n_batches: int = 1, *,
             hw: Chip = TPU_V5E, feats: Features = Features(),
             batch_frac: Optional[float] = None) -> ScheduleResult:
    """Event-driven list scheduling of `n_batches` through the acc pipeline.

    Each batch runs every node in topological order; a node occupies its
    accelerator for node_time(total); inter-acc edges add transfer_time.
    batch_frac: fraction of the graph's global batch each pipelined batch
    carries (default 1/n_batches so the total workload is one global batch —
    matching the paper's "#accs = batch" sweep where the global workload is
    fixed and split into pipelined batches)."""
    nodes = graph.nodes
    frac = batch_frac if batch_frac is not None else 1.0 / n_batches
    n_acc = assign.n_acc

    # per (node) duration on its acc; fixed-config platforms pad every
    # layer to the acc's frozen array config (paper's seq-acc mismatch).
    refs = [None] * n_acc
    if hw.fixed_config:
        from repro.core.costmodel import acc_ref_dims
        for a in range(n_acc):
            refs[a] = acc_ref_dims([nodes[i] for i in assign.nodes_of(a)],
                                   assign.accs[a], frac)
    dur = [node_time(n, assign.accs[assign.acc_of[n.idx]], hw,
                     batch_frac=frac, train=graph.train, feats=feats,
                     ref_dims=refs[assign.acc_of[n.idx]])["total"]
           for n in nodes]
    # inter-acc transfer per edge (u -> v) crossing accs
    def edge_cost(u: int, v: int) -> float:
        au, av = assign.acc_of[u], assign.acc_of[v]
        if au == av:
            return 0.0
        return transfer_time([nodes[u]], assign.accs[au], assign.accs[av],
                             nodes[u].act_out * frac, hw, feats=feats)

    acc_free = [0.0] * n_acc
    busy = [0.0] * n_acc
    finish: Dict[Tuple[int, int], float] = {}   # (batch, node) -> t
    first_batch_end = 0.0
    makespan = 0.0

    # Readiness-driven list scheduling (paper Fig. 5(c)): "assign a layer
    # to the pipeline as soon as its accelerator is available and its
    # dependencies are resolved" — ops from later batches overtake idle
    # accelerators (this is what fills the spatial pipeline).
    n_nodes = len(nodes)
    n_deps = [len(n.deps) for n in nodes]
    children: List[List[int]] = [[] for _ in nodes]
    for n in nodes:
        for d in n.deps:
            children[d].append(n.idx)

    pending = {(b, i): n_deps[i] for b in range(n_batches)
               for i in range(n_nodes)}
    ready: List[Tuple[float, int, int]] = []     # (ready_time, batch, node)
    for b in range(n_batches):
        for i in range(n_nodes):
            if n_deps[i] == 0:
                ready.append((0.0, b, i))

    scheduled = 0
    total = n_batches * n_nodes
    while scheduled < total:
        # pick the op with the earliest feasible start (FIFO tie-break)
        best_j = -1
        best_start = best_key = None
        for j, (rt, b, i) in enumerate(ready):
            a = assign.acc_of[i]
            start = max(rt, acc_free[a])
            key = (start, b, i)
            if best_key is None or key < best_key:
                best_key, best_start, best_j = key, start, j
        rt, b, i = ready.pop(best_j)
        a = assign.acc_of[i]
        end = best_start + dur[i]
        acc_free[a] = end
        busy[a] += dur[i]
        finish[(b, i)] = end
        makespan = max(makespan, end)
        if b == 0:
            first_batch_end = max(first_batch_end, end)
        scheduled += 1
        for ch in children[i]:
            pending[(b, ch)] -= 1
            if pending[(b, ch)] == 0:
                r = max((finish[(b, d)] + edge_cost(d, ch)
                         for d in nodes[ch].deps), default=0.0)
                ready.append((r, b, ch))

    total_flops = graph.total_mm_flops * frac * n_batches
    thr = total_flops / makespan if makespan > 0 else 0.0
    return ScheduleResult(latency=first_batch_end, makespan=makespan,
                          throughput_flops=thr, per_acc_busy=busy)


# ---------------------------------------------------------------------------
# assignment constructors
# ---------------------------------------------------------------------------

def contiguous_assignment(graph: Graph, n_acc: int, total_chips: int,
                          configs: Optional[Sequence[AccConfig]] = None,
                          ) -> Assignment:
    """Split the node list into n_acc contiguous groups balanced by MM FLOPs
    (the natural stage partition for a chain-structured transformer), with
    chips allocated ∝ FLOPs share (paper: "AIE number ∝ #ops")."""
    nodes = graph.nodes
    total = sum(n.mm_flops for n in nodes) or 1.0
    target = total / n_acc
    acc_of = []
    acc = 0
    run = 0.0
    for n in nodes:
        if acc < n_acc - 1 and run >= target * (acc + 1) - 0.5 * n.mm_flops:
            acc += 1
        acc_of.append(acc)
        run += n.mm_flops
    used = max(acc_of) + 1
    if configs is None:
        configs = allocate_chips(graph, acc_of, used, total_chips)
    return Assignment(tuple(acc_of), tuple(configs))


def allocate_chips(graph: Graph, acc_of: Sequence[int], n_acc: int,
                   total_chips: int) -> List[AccConfig]:
    """Chips per acc ∝ FLOPs share, floored to ≥1, sum == total_chips;
    default factorization: as data-parallel as the batch allows, rest TP."""
    flops = [0.0] * n_acc
    for n in graph.nodes:
        flops[acc_of[n.idx]] += n.mm_flops
    total = sum(flops) or 1.0
    raw = [max(1, int(round(f / total * total_chips))) for f in flops]
    # fix rounding to hit the exact chip budget
    while sum(raw) > total_chips:
        raw[raw.index(max(raw))] -= 1
    while sum(raw) < total_chips:
        raw[raw.index(max(raw))] += 1
    out = []
    B = graph.shape.global_batch
    for c in raw:
        dp = _largest_divisor_leq(c, max(1, min(B, c)))
        out.append(AccConfig(chips=c, dp=dp, tp=c // dp))
    return out


def _largest_divisor_leq(c: int, cap: int) -> int:
    for d in range(min(cap, c), 0, -1):
        if c % d == 0:
            return d
    return 1


def sequential_assignment(graph: Graph, total_chips: int,
                          dp: Optional[int] = None) -> Assignment:
    """The paper's 'sequential acc': one monolithic accelerator."""
    c = total_chips
    B = graph.shape.global_batch
    if dp is None:
        dp = _largest_divisor_leq(c, max(1, min(B, c)))
    acc = AccConfig(chips=c, dp=dp, tp=c // dp)
    return Assignment(tuple(0 for _ in graph.nodes), (acc,))


def spatial_assignment(graph: Graph, total_chips: int,
                       max_accs: Optional[int] = None) -> Assignment:
    """The paper's 'fully spatial': one acc per layer (capped by chips).
    On op-granularity graphs this becomes one acc per op ROLE, reused
    across layers — exactly the paper's Fig. 9 DeiT-T spatial design
    (specialized QKV / attention / MLP accelerators)."""
    roles = sorted({n.role for n in graph.nodes if n.role})
    if roles and len(roles) > 2:
        return role_assignment(graph, total_chips, max_accs=max_accs)
    n = len(graph.nodes)
    n_acc = min(n, max_accs or n, total_chips)
    return contiguous_assignment(graph, n_acc, total_chips)


def role_assignment(graph: Graph, total_chips: int,
                    max_accs: Optional[int] = None) -> Assignment:
    """acc per op role (merging smallest-FLOPs roles when chips are few)."""
    role_flops: Dict[str, float] = {}
    for n in graph.nodes:
        role_flops[n.role] = role_flops.get(n.role, 0.0) + n.mm_flops
    cap = min(max_accs or total_chips, total_chips, len(role_flops))
    ranked = sorted(role_flops, key=lambda r: -role_flops[r])
    role_to_acc = {}
    for i, r in enumerate(ranked):
        role_to_acc[r] = min(i, cap - 1)    # tail roles share the last acc
    acc_of = tuple(role_to_acc[n.role] for n in graph.nodes)
    n_acc = max(acc_of) + 1
    configs = allocate_chips(graph, acc_of, n_acc, total_chips)
    return Assignment(acc_of, tuple(configs))
