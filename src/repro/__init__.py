"""repro: SSR (FPGA'24) spatial-sequential hybrid architecture as a
multi-pod JAX framework for TPU v5e.  See README.md / DESIGN.md."""
__version__ = "1.0.0"
