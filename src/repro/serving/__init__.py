from repro.obs import TraceConfig, TrafficSnapshot
from repro.serving.adaptive import (AdaptiveConfig, PlanProfile,
                                    ReplanController)
from repro.serving.engine import (Request, ServingEngine, make_prefill_step,
                                  make_prefill_slot_step, make_serve_step,
                                  make_verify_step, ngram_draft)
