"""Batched serving engine: true per-slot continuous batching (vLLM-style),
optionally plan-driven (a lowered ``ServingPlan``).

The engine owns slot-indexed KV/recurrent caches for its whole lifetime
(batch axis = slots).  Admission prefills a single request (batch 1) and
scatters its cache into the free slot via ``dynamic_update_slice`` — cost
O(prompt), never O(active batch).  Decode is one batched step over all
slots with a **per-slot position vector**: each slot writes its own cache
row, rotates RoPE at its own position, and attends under its own length
mask, so mixed-length requests decode at their correct positions.  A slot
retiring (EOS / max tokens / cache full) never interrupts the other
slots' decode — the freed slot is simply re-prefilled from the queue.

**Plan-driven mode** (``plan=lower_serving(execution_plan, slots, chunk)``)
runs the same engine on a searched ``ExecutionPlan`` (see
``repro.plan.serving``): admission becomes a *chunked prefill* — the
prompt is sliced into ``chunk``-token microbatches that stream through the
plan's stage slices, one stage-step per tick, interleaved with decode so a
long prompt never stalls decode — and the plan's spatial width becomes N
independent *decode replicas*, each owning a partition of the slots and
walking the stage slices per decode step.  This realizes the paper's
hybrid spatial-sequential tradeoff under live traffic: prefill is
pipelined spatially, decode is replicated for latency.

**Paged mode** (``paged=True``) swaps the dense per-slot KV reservation
for a fixed block pool with per-slot block tables, content-hash prefix
sharing (admitted prompts sharing a prefix map the same physical blocks,
refcounted, copy-on-write on first divergent write) and LRU reuse of
released blocks — see ``repro.cache`` and ``docs/serving.md``.  SSM state
and sliding-window rings stay dense; the parity guarantee is unchanged.

**Adaptive mode** (``adapt=AdaptiveConfig(...)``) makes the searched
Pareto front *live*: a re-plan controller (``repro.serving.adaptive``)
watches rolling-window arrival rate, queue depth and TTFT/TPOT against
SLO targets and calls ``replan()`` when a different design point
dominates — swapping between monolithic and plan-driven bindings (or
between plans) WITHOUT dropping requests.  One ``PagedCacheManager``
(global slot ids, one physical pool shared by every decode replica)
makes the swap zero-copy on the paged path: a slot's state is its
block-table row, so migration is a table handoff, never a KV copy.

Guarantee (tested by ``tests/test_serving_parity.py``): the token stream
of every request is exactly equal to an isolated one-shot greedy decode
of that request, regardless of arrival order, prompt-length mix, slot
count — or ServingPlan, or cache layout (dense / paged), or any
sequence of live re-plans.

``serve_step`` — the function the decode-shape dry-runs lower — is one
batched decode step over a fixed slot set and keeps accepting a scalar
``cache_index`` for lock-step decode.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.backend import compat, dispatch
from repro.models.model import Model


def make_serve_step(model: Model):
    """serve_step(params, cache, tokens, cache_index) ->
    (next_tokens, logits, new_cache) — one greedy decode step.
    ``cache_index``: scalar (lock-step) or (B,) per-slot positions.
    ``block_tables``: logical->physical page map when ``cache`` is
    pool-backed (paged engines)."""

    def serve_step(params, cache, tokens, cache_index, positions=None,
                   block_tables=None):
        logits, cache = model.decode_step(params, cache, tokens, cache_index,
                                          positions=positions,
                                          block_tables=block_tables)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], logits, cache

    return serve_step


def make_verify_step(model: Model):
    """verify_step(params, cache, tokens, cache_index, block_tables) ->
    (argmax_tokens, new_cache) — one speculative-verify step.  ``tokens``
    is the (B, K+1) window per slot (current token + K drafted tokens);
    the returned (B, K+1) argmaxes score every window position in one
    batched step, exactly as K+1 sequential greedy decodes would."""

    def verify_step(params, cache, tokens, cache_index, block_tables=None):
        logits, cache = model.decode_step(params, cache, tokens, cache_index,
                                          block_tables=block_tables)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return verify_step


def ngram_draft(ctx, k: int, max_ngram: int = 3) -> List[int]:
    """Model-free prompt-lookup drafting: find the most recent earlier
    occurrence of the context's trailing n-gram (longest n first) and
    propose the up-to-``k`` tokens that followed it.  Returns [] when the
    context never repeats — the tick then falls back to plain decode."""
    L = len(ctx)
    if k <= 0 or L < 2:
        return []
    for n in range(min(max_ngram, L - 1), 0, -1):
        tail = ctx[L - n:]
        for i in range(L - n - 1, -1, -1):
            if np.array_equal(ctx[i:i + n], tail):
                follow = ctx[i + n:i + n + k]
                if len(follow):
                    return [int(t) for t in follow]
    return []


def make_prefill_step(model: Model, max_seq: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_seq)
    return prefill_step


def make_prefill_slot_step(model: Model, max_seq: int):
    """prefill_slot_step(params, full_cache, tokens, slot, length) ->
    (next_token, new_full_cache) — admit ONE request into ONE slot."""

    def prefill_slot_step(params, full_cache, tokens, slot, length):
        logits, new_cache = model.prefill_into_slot(
            params, full_cache, tokens, slot, length, max_seq)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, new_cache

    return prefill_slot_step


def make_prefill_suffix_paged_step(model: Model, max_seq: int):
    """Paged admission, end-to-end: prefill the prompt's unmatched SUFFIX
    directly into the pool (no dense staging buffer, no commit-time
    copy).  ``offset`` counts the warm-prefix tokens already sitting in
    shared pages (0 cold); ``block_tables``/``write_tables`` come from
    ``PagedCacheManager.admit`` — the gather map over all mapped blocks
    and the write map naming only the fresh ones."""

    def prefill_suffix_paged(params, full_cache, tokens, slot, offset,
                             length, block_tables, write_tables):
        logits, new_cache = model.prefill_suffix_paged(
            params, full_cache, tokens, slot, offset, length, max_seq,
            block_tables, write_tables)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, new_cache

    return prefill_suffix_paged


@dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (prompt_len,)
    max_new_tokens: int
    eos_token: Optional[int] = None  # retire the slot on this token
    out_tokens: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0             # wall time of the first output token
    t_done: float = 0.0
    slot: int = -1


@dataclass(eq=False)
class _Inflight:
    """One dispatched-but-undrained decode step (overlap mode).

    ``in_toks[slot]`` is the token whose K/V the step wrote — host-known
    at dispatch only right after activation (the prefill's first token);
    otherwise it is the PREVIOUS step's output and is filled in when that
    step drains (always before this record's own drain)."""
    arrs: List[Any]                   # [(next_tokens_device, a, b)]
    entries: List[Any]                # [(slot, req, pos_written)]
    in_toks: Dict[int, Optional[int]]
    act: int                          # active slots at dispatch


@dataclass
class ServingEngine:
    """Continuous batching over persistent slot-indexed caches.

    prefill_bucket: admitted prompts are right-padded to the next multiple
    of this, bounding jit specializations to O(max_seq / bucket) distinct
    prefill shapes.  Padding is only exact for attention mixers (causal
    masking); patterns with recurrent blocks (mamba/mlstm/slstm) fold the
    pad tokens into the state, so the engine auto-disables bucketing for
    them and prefills at the exact prompt length.

    plan: optional ``repro.plan.ServingPlan`` — run plan-driven (chunked
    prefill through the plan's stages + slot-partitioned spatial decode
    replicas).  Plan mode prefills chunks at exact lengths (the chunk
    size itself bounds jit specializations), so ``prefill_bucket`` is
    ignored.

    paged: pool-backed slot caches — global-attention KV lives in a
    fixed block pool of ``num_blocks`` pages of ``page_size`` tokens,
    addressed through per-slot block tables with content-hash prefix
    sharing, copy-on-write, and LRU reuse (``repro.cache``).  A slot then
    costs ``ceil(live_tokens / page_size)`` blocks instead of a dense
    ``max_seq`` reservation.  SSM state and sliding-window ring caches
    stay dense; a model with no global-attention layer auto-disables
    paging entirely.  ``num_blocks=0`` sizes the pool to the dense
    reservation (sharing then only *frees* blocks); smaller pools admit
    more slots than dense could — admission defers while the pool is
    full.  Plan mode shares ONE pool (and one manager, keyed by global
    slot ids) across all decode replicas: each replica's cache is a view
    whose paged leaves alias the same physical arrays, so prefix blocks
    are shared engine-wide and slot migration between replicas or plans
    (``replan``) is a block-table handoff, never a KV copy.
    """
    model: Model
    params: Any
    slots: int
    max_seq: int
    prefill_bucket: int = 16
    plan: Optional[Any] = None       # repro.plan.ServingPlan
    paged: bool = False
    page_size: int = 16
    num_blocks: int = 0              # 0 = slots * max_seq / page_size
    prefix_cache: bool = True        # paged only: registry lookups +
    #                                  block publication + suffix-only
    #                                  prefill on warm prefixes
    speculate: int = 0               # propose up to K tokens per slot via
    #                                  prompt-lookup n-gram drafting and
    #                                  verify them in one batched step
    #                                  (greedy verify: bit-identical to
    #                                  one-shot decode); 0 = off
    overlap: bool = False            # async runtime: decode step N+1 is
    #                                  dispatched before step N's tokens
    #                                  are read back (one-step-delayed
    #                                  drain).  Token streams stay
    #                                  bit-identical to sync mode; the
    #                                  only scheduling difference is that
    #                                  EOS/budget retirement frees a slot
    #                                  one tick later.  Speculation needs
    #                                  the drafts on host each tick, so an
    #                                  effective speculate > 0 forces sync.
    kv_dtype: str = "fp"             # "int8": paged K/V pools store
    #                                  per-row symmetric int8 + f32 scale
    #                                  — ~1.9x (bf16) / ~3.9x (f32) the
    #                                  tokens per byte of the fp layout
    #                                  (see stats()["cache"]
    #                                  ["kv_capacity_x"]); fp is bit-exact
    adapt: Optional[Any] = None      # repro.serving.adaptive.AdaptiveConfig:
    #                                  traffic-adaptive re-planning — a
    #                                  controller scores the candidate
    #                                  design points each tick window and
    #                                  calls replan() when one dominates
    trace: Optional[Any] = None      # repro.obs.TraceConfig (or True for
    #                                  defaults): record request-lifecycle
    #                                  + per-stage/per-replica spans into a
    #                                  ring-buffered Tracer (write_trace()
    #                                  exports Perfetto JSON).  None = off:
    #                                  the hot path allocates no event
    #                                  records at all

    def __post_init__(self):
        from repro.models import transformer as T
        self.cfg = self.model.cfg
        # the engine's prefill/decode steps execute their hot kernels via
        # the dispatch front door (repro.backend.dispatch) inside the model;
        # record the resolved path so serving stats name what actually ran.
        self.kernel_path = dispatch.kernel_path()
        # every step that consumes the engine's cache donates it: the
        # engine always rebinds ``self._cache``/``self._caches[r]`` to the
        # step's output, so the input buffers are dead on return and the
        # runtime may update pages in place (a no-op where the backend
        # ignores donation — compat suppresses the advisory warning)
        self.serve_step = compat.donating_jit(make_serve_step(self.model),
                                              donate_argnums=(1,))
        self._prefill_slot = compat.donating_jit(
            make_prefill_slot_step(self.model, self.max_seq),
            donate_argnums=(1,))
        if any(not b.mixer.startswith("attn") or b.ffn == "moe"
               for b in self.cfg.block_pattern):
            # pad tokens are only exactly neutral under causal attention +
            # dense FFN: recurrent mixers fold them into the state, and MoE
            # routing lets them compete for expert capacity.  Prefill those
            # families at the exact prompt length instead.
            self.prefill_bucket = 1
        # smallest sliding-window ring among the mixers: a padded prompt
        # may never spill past it (the ring's tail write would keep pad
        # k/v and evict real tokens the gold decode still attends).
        self._ring_min = min(
            (min(self.max_seq, self.cfg.window_size)
             for b in self.cfg.block_pattern if b.mixer == "attn_local"),
            default=0)
        if self.paged and not T.has_paged_layers(self.cfg):
            # nothing to page: every mixer keeps dense state (SSM) or a
            # dense ring (local windows) — run the dense engine wholesale.
            self.paged = False
        # suffix-only prefill on warm prefixes: only when the prefill is
        # suffix-decomposable (all-global-attention, no MoE) — block-level
        # MEMORY sharing stays on for every paged family regardless.
        self._suffix_reuse = (self.paged and self.prefix_cache
                              and T.supports_prefix_compute_reuse(self.cfg))
        # speculative decode: same decomposability gate as suffix reuse —
        # a verify window replays K+1 positions through the cache, which
        # is exact only when every mixer is global attention and the FFN
        # is dense (ring wraps lose overwritten rows, SSM state cannot
        # rewind a rejected tail, MoE capacity couples window rows).
        self._spec_k = (self.speculate
                        if (self.speculate > 0
                            and T.supports_prefix_compute_reuse(self.cfg))
                        else 0)
        if self._spec_k:
            # built regardless of plan mode: a re-plan to monolithic must
            # be able to verify without mid-traffic setup (jit compiles
            # lazily, so an unused wrapper costs nothing)
            self._verify_step = compat.donating_jit(
                make_verify_step(self.model), donate_argnums=(1,))
        if self.kv_dtype not in ("fp", "int8"):
            raise ValueError(
                f"kv_dtype={self.kv_dtype!r} must be 'fp' or 'int8'")
        if self.kv_dtype != "fp" and not self.paged:
            raise ValueError(
                "kv_dtype='int8' quantizes paged K/V pools — pass "
                "paged=True (and use a model with global-attention "
                "layers to page)")
        if self.paged:
            if self.max_seq % self.page_size:
                raise ValueError(
                    f"paged serving needs max_seq ({self.max_seq}) "
                    f"divisible by page_size ({self.page_size})")
            self._prefill_suffix_paged = compat.donating_jit(
                make_prefill_suffix_paged_step(self.model, self.max_seq),
                donate_argnums=(1,))
            self._copy_pages = compat.donating_jit(T.copy_cache_pages,
                                                   donate_argnums=(0,))
            self._scatter_paged = compat.donating_jit(T.scatter_prefill_part,
                                                      donate_argnums=(0,))
        # engine-lifetime state -------------------------------------------
        self._pf = None
        self._pager = None               # ONE PagedCacheManager for the
        #                                  whole engine, monolithic AND
        #                                  plan mode: rows are global slot
        #                                  ids and every decode replica's
        #                                  cache fronts the same physical
        #                                  pool, so prefix blocks share
        #                                  engine-wide and slot migration
        #                                  (replan / work stealing) is a
        #                                  table handoff, never a KV copy
        self._admit_plans = {}           # slot -> AdmitPlan (mid-prefill)
        self._rt = None
        self._rt_cache = {}              # ServingPlan -> PlanRuntime: a
        #                                  re-plan returning to a seen
        #                                  point reuses its compiled fns
        self._caches = None
        bps = self.max_seq // self.page_size if self.paged else 0
        self._total_blocks = (self.num_blocks or self.slots * bps
                              if self.paged else 0)
        if self.paged:
            from repro.cache import PagedCacheManager
            self._pager = PagedCacheManager(
                self.slots, self.max_seq, self.page_size,
                self._total_blocks,
                prefix_cache=self.prefix_cache, kv_dtype=self.kv_dtype,
                kv_capacity_ratio=T.paged_kv_capacity_ratio(
                    self.cfg, self.kv_dtype))
        if self.plan is not None:
            from repro.plan.serving import PrefillPipeline
            if self.plan.slots != self.slots:
                raise ValueError(
                    f"ServingPlan was lowered for {self.plan.slots} slots "
                    f"but the engine has {self.slots}; re-lower via "
                    f"lower_serving(plan, slots={self.slots})")
            self._rt = self._runtime_for(self.plan)
            self._pf = PrefillPipeline(self._rt, self.params)
            # one cache VIEW per decode replica (its slot partition is
            # the dense batch axis); paged views alias one shared pool
            self._caches = self._replica_views(self.plan, self._full_init())
            self._cache = None
            self.prefill_bucket = 1       # chunks run at exact lengths
        else:
            self._cache = self._full_init()
        self._pos = np.zeros((self.slots,), np.int32)    # tokens in cache
        self._cur = np.zeros((self.slots, 1), np.int32)  # next input token
        # overlap (async) runtime state: an effective speculate forces
        # sync, plan-less and plan-driven engines both support overlap
        self._overlap = bool(self.overlap) and self._spec_k == 0
        self._inflight: List[_Inflight] = []   # dispatched, undrained steps
        self._cur_dev = None             # device-side token chain: the last
        #                                  dispatched step's output array
        #                                  (so step N+1's inputs never
        #                                  round-trip through the host)
        self._cur_known = np.ones((self.slots,), bool)  # _cur[s] current?
        self._slot_req: List[Optional[Request]] = [None] * self.slots
        self._reserved = set()           # slots mid-(chunked)-prefill
        self.queue: List[Request] = []
        self.done: List[Request] = []
        # CONCURRENT peak across the per-replica pools: per-pool peaks
        # occur on different ticks, so summing them would overstate the
        # true footprint (and understate effective_slots_gain)
        from repro.cache import ConcurrentPeakTracker
        self._peak_tracker = ConcurrentPeakTracker()
        for pager in self._all_pagers():
            self._peak_tracker.attach(pager.pool)
        # stats ------------------------------------------------------------
        self.decode_steps = 0
        self.decode_tokens = 0            # tokens emitted by decode ticks
        self._occupied_step_sum = 0       # sum over steps of occupied slots
        self._decode_slot_steps = 0       # active slots at decode DISPATCH:
        #   each dispatched slot emits >= 1 token, so decode_tokens over
        #   this is exactly 1.0 for plain decode and > 1 when speculative
        #   verify accepts drafts ( _occupied_step_sum is sampled after
        #   retirement, so it undercounts the step that finishes a slot)
        self.prefill_batch_sizes: List[int] = []  # always 1 per admission
        self.prefill_token_counts: List[int] = []
        self.prefill_chunk_counts: List[int] = []  # chunks per admission
        self.ticks = 0
        self.spec_steps = 0               # decode ticks that ran a verify
        self.spec_proposed = 0            # drafted tokens offered to verify
        self.spec_accepted = 0            # drafted tokens accepted
        self.replans = 0                  # live plan swaps this window
        self.migrations = 0               # slots moved (work stealing)
        self.migration_copies = 0         # device row moves those cost —
        #                                  stays 0 on the paged path for
        #                                  models with no dense slot
        #                                  leaves (the zero-copy claim)
        # host wall-clock per engine phase, accumulated across ticks.
        # "host_sync" is an OVERLAY bucket, not a partition member: it
        # accrues inside whichever phase window is open and measures how
        # much of that phase the host spent blocked on device readback
        # (the quantity the async runtime shrinks) — see _sync().
        # "replan" charges controller decisions + live swaps, so the
        # migration interval is accounted, never lost or double-counted.
        # "idle" is the host wall between one tick's end and the next
        # tick's start (queue-empty waits, caller think time), so the
        # partition buckets (all but the host_sync overlay) sum to the
        # first-tick-start → last-tick-end wall.
        self.phase_time = {"admission": 0.0, "prefill": 0.0, "decode": 0.0,
                           "replan": 0.0, "idle": 0.0, "host_sync": 0.0}
        self._prefill_window = 0.0        # prefill seconds inside _admit()
        self._t_window = time.perf_counter()  # stats window start (reset_stats)
        self._t_tick_end = None           # end of the previous tick (idle
        #                                   accrues from here to the next
        #                                   tick's entry)
        self._t_first_tick = None         # first tick entry this window
        self.submitted = 0                # lifetime submissions (monotonic)
        self._arrival_log = []            # (t_submit, prompt_len, max_new)
        #                                  ring consumed by the controller
        self._ctl = None
        if self.adapt is not None:
            from repro.serving.adaptive import ReplanController
            self._ctl = ReplanController(self.adapt)
            self._ctl.validate(self)
        # observability ----------------------------------------------------
        # Always on: a MetricsRegistry (one histogram observe + two counter
        # incs per retirement) and per-stage / per-replica utilization
        # accumulators (integer adds per decode dispatch).  Strictly
        # opt-in: the Tracer — self._tr stays None unless trace= is passed
        # (or enable_trace() is called) and every emission site is guarded
        # on it, so the disabled hot path allocates no event records.
        from repro.obs import MetricsRegistry, TPOT_BUCKETS, TTFT_BUCKETS
        self.metrics = MetricsRegistry()
        self._h_ttft = self.metrics.histogram(
            "repro_ttft_seconds", TTFT_BUCKETS,
            help="time to first token per retired request")
        self._h_tpot = self.metrics.histogram(
            "repro_tpot_seconds", TPOT_BUCKETS,
            help="time per output token per retired request")
        self._c_requests = self.metrics.counter(
            "repro_requests_total", help="requests retired")
        self._c_gen = self.metrics.counter(
            "repro_tokens_generated_total", help="tokens generated")
        self._stage_busy = {}             # stage idx -> busy stage-steps
        self._pipeline_ticks = 0          # ticks the prefill pipeline ran
        self._replica_busy = {}           # replica -> occupied slot-steps
        self._replica_cap = {}            # replica -> dispatched capacity
        self._tr = None
        self.trace_config = None
        if self.trace:
            self.enable_trace(self.trace)

    # -- public API --------------------------------------------------------
    def submit(self, req: Request):
        if len(req.prompt) >= self.max_seq:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens cannot fit a "
                f"max_seq={self.max_seq} slot cache")
        req.t_submit = time.perf_counter()
        self.queue.append(req)
        self.submitted += 1
        self._arrival_log.append((req.t_submit, len(req.prompt),
                                  req.max_new_tokens))
        if len(self._arrival_log) > 4 * self.slots + 256:
            del self._arrival_log[:len(self._arrival_log) // 2]
        if self._tr is not None:
            self._tr.instant("requests", "submit", t=req.t_submit, args={
                "uid": req.uid, "prompt_tokens": len(req.prompt),
                "max_new": req.max_new_tokens})

    def enable_trace(self, cfg: Any = True):
        """Attach a fresh ring-buffered Tracer (``repro.obs.Tracer``) to
        the engine and its prefill pipeline.  ``cfg`` is a
        ``repro.obs.TraceConfig`` (or ``True`` for defaults).  Can be
        called mid-serve — e.g. benchmarks measure with tracing off, then
        trace one extra round for the artifact.  Returns the tracer."""
        from repro.obs import TraceConfig, Tracer
        if cfg is True or cfg is None:
            cfg = TraceConfig()
        self.trace_config = cfg
        self._tr = Tracer(cfg.capacity)
        if self._pf is not None:
            self._pf.tracer = self._tr
        return self._tr

    @property
    def active(self) -> int:
        return sum(r is not None for r in self._slot_req)

    def tick(self) -> bool:
        """Admit whatever fits, advance any in-flight chunked prefills by
        one stage-step, then run one batched decode step per replica.
        Returns True while there is (or may be) work in flight.

        Each phase's host wall-clock accrues in ``phase_time`` (the
        prefill compute launched inside admission is credited to
        "prefill", so "admission" is pure bookkeeping — block matching,
        allocation, padding).

        Overlap mode reorders the decode phase: this tick's step is
        DISPATCHED first (its inputs are the previous step's output array,
        still on device), and only then is the previous step's result
        read back — so the device computes step N while the host drains
        step N-1 and runs the next tick's admission bookkeeping.  The
        drained tokens retire slots exactly as sync mode does, one tick
        later; the per-request token streams are identical."""
        t_enter = time.perf_counter()
        if self._t_tick_end is not None:
            # host wall between ticks: queue-empty waits / caller think
            # time — the bucket that makes phase_time_s sum to the wall
            self.phase_time["idle"] += t_enter - self._t_tick_end
        if self._t_first_tick is None:
            self._t_first_tick = t_enter
        tr = self._tr
        if tr is not None:
            tr.counter("tick", "engine", {"queue": len(self.queue),
                                          "active": self.active}, t=t_enter)
        if self._ctl is not None and not self._ctl.paused:
            tc = time.perf_counter()
            decision = self._ctl.observe(self)  # None = keep, (plan,) = swap
            self.phase_time["replan"] += time.perf_counter() - tc
            if tr is not None and self._ctl.last_scores is not None:
                # a decision tick: record what the controller weighed
                tr.instant("tick", "replan_decision", args={
                    "scores": self._ctl.last_scores,
                    "decision": ("keep" if decision is None else
                                 (decision[0].label
                                  if decision[0] is not None else "mono"))})
            if decision is not None:
                self.replan(decision[0])
        t0 = time.perf_counter()
        self._prefill_window = 0.0
        q0 = len(self.queue)
        self._admit()
        t1 = time.perf_counter()
        self.phase_time["admission"] += (t1 - t0) - self._prefill_window
        self.phase_time["prefill"] += self._prefill_window
        if tr is not None and q0:
            tr.span("tick", "admission", t0, t1, args={
                "queued": q0, "admitted": q0 - len(self.queue),
                "plan": self.plan.label if self.plan is not None else "mono"})
        if self._pf is not None and self._pf.busy:
            # paged stage steps thread the replica caches; after a re-plan
            # to monolithic the drained items route through a one-entry
            # list wrapping the monolithic cache (item.replica remapped 0)
            if self.paged:
                clist = self._caches if self.plan is not None \
                    else [self._cache]
            else:
                clist = self._caches if self.plan is not None else None
            finished = self._pf.step(caches=clist,
                                     on_chunk=self._chunk_committed)
            self._pipeline_ticks += 1
            for s in self._pf.last_stages_run:
                self._stage_busy[s] = self._stage_busy.get(s, 0) + 1
            if self.paged and self.plan is None:
                self._cache = clist[0]
            for item in finished:
                self._finish_prefill(item)
            self.phase_time["prefill"] += time.perf_counter() - t1
        if self._pf is not None and self.plan is None and not self._pf.busy:
            self._pf = None       # old pipeline fully drained post-re-plan
        if self.active or self._inflight:
            t2 = time.perf_counter()
            dispatched = False
            if self.active:
                if self._overlap:
                    self._dispatch_decode()
                    dispatched = True
                else:
                    self._decode_once()
            # one-step-delayed drain: the newest dispatch stays in flight
            # while its predecessor's tokens come back; once nothing new
            # dispatches, drain everything so the last slots retire.
            while len(self._inflight) > (1 if dispatched else 0):
                self._drain_one()
            self.phase_time["decode"] += time.perf_counter() - t2
        self.ticks += 1
        self._t_tick_end = time.perf_counter()
        return bool(self.active or self.queue or self._inflight
                    or (self._pf is not None and self._pf.busy))

    def run(self, max_steps: int = 10_000):
        """Drive ticks until every submitted request retires."""
        steps = 0
        while self.tick() and steps < max_steps:
            steps += 1
        return self.done

    def replan(self, plan, *, rebalance: bool = True):
        """Swap the engine onto a different ``ServingPlan`` (None =
        monolithic) WITHOUT dropping requests — the online Pareto move.

        What moves, and what does not:
          * paged K/V: never.  Every replica fronts ONE physical pool and
            ONE ``PagedCacheManager`` whose rows are global slot ids, so
            a slot's paged state is plan-independent — re-binding decode
            to a new replica partition is pure pytree restructuring
            (``slice_cache_slots`` / ``concat_cache_slots`` pass pool
            leaves through untouched).  ``migration_copies`` stays 0.
          * dense slot leaves (SSM state, local-window rings; every leaf
            on a dense engine): re-layout between partitions by slot-axis
            concat/slice.  All-global-attention paged models have none —
            their swap does no device work at all.
          * in-flight chunked prefills: drain-and-rebind — remaining
            chunks finish on the runtime they were admitted under
            (bit-exact stage walk), only their replica-cache routing is
            remapped; decode re-binds to the new plan immediately.
          * overlap mode: the undrained steps land first (the same drain
            the final ticks run — token streams are unaffected).

        Stats windows are CONTINUOUS across the swap: no counter resets,
        the swap's own wall time accrues in ``phase_time["replan"]``, and
        the surviving pool re-attaches to the engine-lifetime peak
        tracker (idempotent), so the concurrent peak spans the swap.

        ``replan(current_plan)`` degenerates to pure cross-replica work
        stealing: re-balance the active slots over the replicas with
        zero-copy migrations (``rebalance=False`` suppresses it)."""
        from repro.models import transformer as T
        t0 = time.perf_counter()
        old_label = self.plan.label if self.plan is not None else "mono"
        if plan is not None and plan.slots != self.slots:
            raise ValueError(
                f"ServingPlan was lowered for {plan.slots} slots "
                f"but the engine has {self.slots}; re-lower via "
                f"lower_serving(plan, slots={self.slots})")
        if plan == self.plan:
            if rebalance and self.plan is not None:
                self._drain_inflight()
                self._rebalance_slots()
                if self._tr is not None:
                    self._tr.span("tick", "rebalance", t0, args={
                        "plan": old_label, "migrations": self.migrations})
            self.phase_time["replan"] += time.perf_counter() - t0
            return
        # 1. land everything in flight on the old binding
        self._drain_inflight()
        # 2. collect the full slot-axis cache (dense leaves concat in
        #    replica order — partitions are contiguous ascending slot
        #    ranges; shared paged pool leaves pass through as-is)
        if self.plan is not None:
            full = T.concat_cache_slots(self._caches)
        else:
            full = self._cache
        # 3. drain-and-rebind the prefill pipeline: in-flight items keep
        #    their admission runtime (item.rt); only the replica-cache
        #    routing is remapped to wherever their slot lives now
        items = list(self._pf.items) if self._pf is not None else []
        if plan is not None:
            from repro.plan.serving import PrefillPipeline
            self._rt = self._runtime_for(plan)
            pf = PrefillPipeline(self._rt, self.params, tracer=self._tr)
            pf.adopt(items)
            self._pf = pf
        else:
            self._rt = None
            if not items:
                self._pf = None
            # else: the old pipeline survives solely to drain its items
            # (tick() routes it the monolithic cache and drops it dry)
        for it in items:
            it.replica, it.local_slot = ((0, it.slot) if plan is None
                                         else plan.replica_of_slot(it.slot))
        # 4. re-bind decode to the new replica partition
        self.plan = plan
        if plan is not None:
            self._caches = self._replica_views(plan, full)
            self._cache = None
        else:
            self._cache = full
            self._caches = None
        # 5. the pool and its manager survive verbatim (rows are global
        #    slot ids) — re-attach to the engine-lifetime peak tracker
        for pager in self._all_pagers():
            self._peak_tracker.attach(pager.pool)
        # 6. spread the surviving decode slots over the new replicas
        if rebalance and plan is not None:
            self._rebalance_slots()
        self.replans += 1
        self.phase_time["replan"] += time.perf_counter() - t0
        if self._tr is not None:
            self._tr.span("tick", "replan", t0, args={
                "from": old_label,
                "to": plan.label if plan is not None else "mono",
                "migrations": self.migrations,
                "migration_copies": self.migration_copies})

    def warm_replans(self):
        """Exercise every adaptive candidate once (measured profiles,
        runtimes, and a tiny end-to-end request per candidate) so jitted
        paths compile outside the measured window, then restore the
        initial binding.  Call before ``reset_stats()`` in benchmarks."""
        if self._ctl is None:
            return
        initial = self.plan
        self._ctl.paused = True
        try:
            self._ctl.warm(self)
            uid = -1
            for cand in self._ctl.cfg.plans:
                self.replan(cand, rebalance=False)
                chunk = cand.chunk if cand is not None else 4
                prompt = np.ones((max(2 * chunk, 4),), np.int32)
                self.submit(Request(uid=uid, prompt=prompt,
                                    max_new_tokens=3))
                uid -= 1
                self.run()
            self.replan(initial, rebalance=False)
        finally:
            self._ctl.paused = False

    def _drain_inflight(self):
        """Retire the async runtime's undrained steps (overlap mode): a
        re-plan re-binds the decode caches, so every dispatched step must
        land first.  The same drain the final ticks run — token streams
        are unaffected."""
        while self._inflight:
            self._drain_one()
        self._cur_dev = None

    def _rebalance_slots(self):
        """Cross-replica work stealing: migrate active decode slots from
        overloaded replicas onto free slots of underloaded ones until no
        replica holds 2+ more than another.  Mid-prefill (reserved) slots
        stay put — their chunks are still streaming.  Paged moves are
        block-table handoffs (zero KV copies)."""
        plan = self.plan
        R = plan.n_replicas
        while True:
            load = [0] * R
            for s in range(self.slots):
                if self._slot_req[s] is not None or s in self._reserved:
                    load[plan.replica_of_slot(s)[0]] += 1
            hi = max(range(R), key=lambda r: load[r])
            lo = min(range(R), key=lambda r: load[r])
            if load[hi] - load[lo] <= 1:
                return
            a, b = plan.replica_range(hi)
            movable = [s for s in range(a, b)
                       if self._slot_req[s] is not None
                       and s not in self._reserved]
            la, lb = plan.replica_range(lo)
            dsts = [s for s in range(la, lb)
                    if self._slot_req[s] is None
                    and s not in self._reserved]
            if not movable or not dsts:
                return        # surplus is all mid-prefill: nothing to steal
            self._migrate_slot(movable[-1], dsts[0])

    def _migrate_slot(self, src: int, dst: int):
        """Move one ACTIVE decode request between slots (and thus
        replicas).  Paged path: the slot's state IS its block-table row —
        ``PagedCacheManager.migrate_slot`` hands the row over and no KV
        moves.  Dense slot leaves (SSM state, rings; all leaves on a
        dense engine) move one batch row on device; ``migration_copies``
        counts those and stays 0 for all-global-attention paged models."""
        from repro.models import transformer as T
        assert self._slot_req[dst] is None and dst not in self._reserved
        assert src not in self._reserved
        req = self._slot_req[src]
        if self._pager is not None:
            self._pager.migrate_slot(src, dst)
        rs, ls = self.plan.replica_of_slot(src)
        rd, ld = self.plan.replica_of_slot(dst)
        part = T.extract_dense_slot(self._caches[rs], ls)
        if part:
            if self.paged:
                self._caches[rd] = self._scatter_paged(
                    self._caches[rd], part, jnp.int32(ld))
                self._share_pool(rd)
            else:
                self._caches[rd] = T.scatter_cache_slot(
                    self._caches[rd], part, jnp.int32(ld))
            self.migration_copies += 1
        self._slot_req[dst] = req
        self._slot_req[src] = None
        req.slot = dst
        self._pos[dst] = self._pos[src]
        self._pos[src] = 0
        self._cur[dst] = self._cur[src]
        self._cur_known[dst] = self._cur_known[src]
        self._cur_known[src] = True
        self.migrations += 1

    def reset_stats(self):
        """Zero the counters (e.g. after a compile-warmup run) so stats()
        reports only the measured window.  Active slots (and the blocks
        they reference) are untouched; pool *counters* reset so reuse and
        copy-on-write rates cover only the measured window."""
        self.done = []
        self.decode_steps = 0
        self.decode_tokens = 0
        self._occupied_step_sum = 0
        self._decode_slot_steps = 0
        self.prefill_batch_sizes = []
        self.prefill_token_counts = []
        self.prefill_chunk_counts = []
        self.ticks = 0
        self.spec_steps = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.replans = 0
        self.migrations = 0
        self.migration_copies = 0
        self.phase_time = {"admission": 0.0, "prefill": 0.0, "decode": 0.0,
                           "replan": 0.0, "idle": 0.0, "host_sync": 0.0}
        self._stage_busy = {}
        self._pipeline_ticks = 0
        self._replica_busy = {}
        self._replica_cap = {}
        self.metrics.reset()
        # requests already in flight keep their pre-reset t_submit; the
        # stats() wall window clamps to this timestamp so the measured
        # window never reaches back before the reset
        self._t_window = time.perf_counter()
        self._t_tick_end = None       # idle accrues only BETWEEN ticks of
        self._t_first_tick = None     # the new window
        self._peak_tracker.reset()
        for pager in self._all_pagers():
            p = pager.pool
            p.prefix_queries = p.prefix_hits = 0
            p.cow_copies = p.evictions = 0
            p.peak_in_use = p.blocks_in_use
            p.prefill_admissions = p.prefill_compute_hits = 0
            p.reused_prefill_tokens = p.suffix_prefill_tokens = 0
            pager.migrations = 0

    def _all_pagers(self):
        return [self._pager] if self._pager is not None else []

    def cache_stats(self) -> Dict[str, Any]:
        """Cache memory utilization: live vs reserved tokens, and for
        paged engines the block-pool picture (occupancy, prefix-reuse hit
        rate, copy-on-write/eviction counts, and the effective-slots gain
        — how many dense slot reservations the peak paged footprint
        actually amounted to)."""
        live = int(sum(int(self._pos[s]) for s in range(self.slots)
                       if self._slot_req[s] is not None))
        reserved = self.slots * self.max_seq
        out: Dict[str, Any] = {
            "layout": "paged" if self.paged else "dense",
            "live_tokens": live,
            "reserved_tokens": reserved,
            "utilization": live / reserved if reserved else 0.0,
        }
        pagers = self._all_pagers()
        if pagers:
            # kv layout keys are properties of the (shared) pool dtype,
            # not additive counters — every replica pool carries the same
            agg = {k: sum(p.stats()[k] for p in pagers)
                   for k in pagers[0].stats()
                   if k not in ("kv_dtype", "kv_capacity_x")}
            agg["kv_dtype"] = pagers[0].kv_dtype
            agg["kv_capacity_x"] = pagers[0].kv_capacity_ratio
            agg["page_size"] = self.page_size
            agg["reuse_hit_rate"] = (
                agg["prefix_hits"] / max(agg["prefix_queries"], 1))
            # non-additive keys: recompute over the aggregate
            agg["prefix_cache"] = self.prefix_cache
            agg["prefill_hit_rate"] = (
                agg["prefill_compute_hits"]
                / max(agg["prefill_admissions"], 1))
            # per-pool peaks occur on different ticks: report the tracked
            # CONCURRENT peak, not the sum of per-pool maxima
            agg["peak_blocks_in_use"] = self._peak_tracker.peak
            dense_blocks = self.slots * (self.max_seq // self.page_size)
            agg["effective_slots_gain"] = (
                dense_blocks / max(agg["peak_blocks_in_use"], 1))
            out.update(agg)
        return out

    def utilization_stats(self) -> Dict[str, Any]:
        """Windowed pipeline/replica utilization from the always-on
        accumulators — the paper's utilization story in numbers.  A pure
        read (snapshot): repeated calls in one window return equal dicts.

          * ``stage_bubble_frac[s]``: fraction of busy-pipeline ticks on
            which prefill stage ``s`` ran NO chunk (pipeline bubbles);
          * ``replica_occupancy[r]``: occupied slot-steps / dispatched
            slot-step capacity of decode replica ``r`` (mono = replica 0
            over all slots);
          * ``replica_load_spread``: max-min occupancy gap (0 = balanced
            — what ``_rebalance_slots`` drives toward);
          * speculation acceptance + prefix compute-hit rates, the two
            signals ROADMAP item 3 wants priced into the cost model."""
        pt = self._pipeline_ticks
        n_stages = self.plan.n_stages if self.plan is not None else 0
        if self._stage_busy:
            n_stages = max(n_stages, max(self._stage_busy) + 1)
        bubbles = ({s: 1.0 - self._stage_busy.get(s, 0) / pt
                    for s in range(n_stages)} if pt else {})
        occ = {r: self._replica_busy.get(r, 0) / c
               for r, c in sorted(self._replica_cap.items()) if c}
        spread = (max(occ.values()) - min(occ.values())) if occ else 0.0
        hits = queries = 0
        for p in self._all_pagers():
            ps = p.stats()
            hits += ps["prefill_compute_hits"]
            queries += ps["prefill_admissions"]
        return {
            "pipeline_ticks": pt,
            "stage_busy_ticks": dict(sorted(self._stage_busy.items())),
            "stage_bubble_frac": bubbles,
            "replica_occupancy": occ,
            "replica_load_spread": spread,
            "spec_acceptance_rate": (self.spec_accepted
                                     / max(self.spec_proposed, 1)),
            "prefix_hit_rate": hits / max(queries, 1),
        }

    def traffic_snapshot(self, window_s: float = 2.0, *,
                         slo_ttft_s: float = 0.0, slo_tpot_s: float = 0.0,
                         horizon_s: float = 0.0):
        """One typed observation of live traffic (``TrafficSnapshot``), or
        None when the engine is idle — what the adaptive controller reads
        each decision window instead of poking engine internals."""
        from repro.obs import TrafficSnapshot
        now = time.perf_counter()
        w = max(window_s, 1e-6)
        recent = [(t, pl, mn) for t, pl, mn in self._arrival_log
                  if t >= now - w]
        lam = len(recent) / w
        avg_prompt = (float(np.mean([pl for _, pl, _ in recent]))
                      if recent else 0.0)
        avg_new = (float(np.mean([mn for _, _, mn in recent]))
                   if recent else 0.0)
        queued_tok = float(sum(len(r.prompt) for r in self.queue))
        rem = [r.max_new_tokens - len(r.out_tokens)
               for r in self._slot_req if r is not None]
        depth = float(np.mean(rem)) if rem else 0.0
        # forecast decode depth for work that has not prefilled yet
        incoming = len(self.queue) + lam * horizon_s
        if incoming > 0 and avg_new > 0:
            depth = max(depth, avg_new)
        if not rem and not self.queue and not recent:
            return None                      # idle: nothing to navigate
        violated = False
        if slo_ttft_s > 0:
            tail = self.done[-8:]
            if any(r.t_first - r.t_submit > slo_ttft_s for r in tail):
                violated = True
            if self.queue and now - self.queue[0].t_submit > slo_ttft_s:
                violated = True
        if slo_tpot_s > 0:
            for r in self.done[-8:]:
                n = max(len(r.out_tokens) - 1, 1)
                if (r.t_done - r.t_first) / n > slo_tpot_s:
                    violated = True
        return TrafficSnapshot(
            lam=lam, avg_prompt=avg_prompt, avg_new=avg_new,
            queued_tok=queued_tok, depth=depth, queue_len=len(self.queue),
            active=self.active, violated=violated, window_s=w)

    def export_metrics(self):
        """Fold the current ``stats()`` snapshot into the engine's
        ``MetricsRegistry`` as gauges (idempotent — gauges are set, never
        accrued) and return the registry, ready for ``to_prometheus()``
        or ``repro.obs.write_metrics``."""
        from repro.obs import fold_engine_metrics
        fold_engine_metrics(self.metrics, self.stats())
        return self.metrics

    def write_trace(self, path: str):
        """Export the tracer's retained records as Perfetto trace_event
        JSON (open at ui.perfetto.dev).  Requires tracing on."""
        from repro.obs import write_trace
        if self._tr is None:
            raise ValueError(
                "tracing is off: pass trace=TraceConfig() at construction "
                "or call enable_trace() first")
        write_trace(self._tr, path)

    def stats(self) -> Dict[str, Any]:
        """Serving-side latency/throughput numbers for the SSR story."""
        reqs = self.done
        gen = sum(len(r.out_tokens) for r in reqs)
        if reqs:
            # clamp submits to the measurement-window start: a request
            # active across reset_stats() keeps its pre-reset t_submit,
            # which must not stretch the post-reset wall window
            t0 = min(max(r.t_submit, self._t_window) for r in reqs)
            wall = max(r.t_done for r in reqs) - t0
        else:
            wall = 0.0
        cap = max(self.decode_steps * self.slots, 1)
        out = {
            "kernel_path": self.kernel_path,
            "requests": len(reqs),
            "gen_tokens": gen,
            "decode_steps": self.decode_steps,
            "decode_tokens": self.decode_tokens,
            # per-slot tokens per decode step: exactly 1.0 for plain
            # decode, > 1 when speculation is accepting drafted tokens
            "tokens_per_step": (self.decode_tokens
                                / max(self._decode_slot_steps, 1)),
            "spec_steps": self.spec_steps,
            "spec_proposed": self.spec_proposed,
            "spec_accepted": self.spec_accepted,
            "acceptance_rate": (self.spec_accepted
                                / max(self.spec_proposed, 1)),
            "slot_occupancy": self._occupied_step_sum / cap,
            "replans": self.replans,
            "migrations": self.migrations,
            "migration_copies": self.migration_copies,
            "throughput_tok_s": gen / wall if wall > 0 else 0.0,
            "ttft_s": [r.t_first - r.t_submit for r in reqs],
            "latency_s": [r.t_done - r.t_submit for r in reqs],
            "ticks": self.ticks,
            "phase_time_s": dict(self.phase_time),
            "cache": self.cache_stats(),
            "utilization": self.utilization_stats(),
        }
        out["plan_label"] = (self.plan.label if self.plan is not None
                             else "mono")
        if self.plan is not None:
            out["plan_stages"] = self.plan.n_stages
            out["decode_replicas"] = self.plan.n_replicas
            out["prefill_chunk"] = self.plan.chunk
        return out

    # -- internals ---------------------------------------------------------
    def _sync(self, x) -> np.ndarray:
        """Block on a device value and charge the wait to the
        ``host_sync`` phase bucket.  The bucket overlays the phase
        windows (the wait also sits inside whichever phase is open): it
        measures how much host wall-clock was spent blocked on device
        readback — the quantity the overlap runtime removes from the
        critical path by dispatching the next step first."""
        t0 = time.perf_counter()
        arr = np.asarray(x)
        self.phase_time["host_sync"] += time.perf_counter() - t0
        return arr

    def _padded_len(self, n: int) -> int:
        b = max(self.prefill_bucket, 1)
        pp = min(-(-n // b) * b, self.max_seq - 1)
        if self._ring_min:
            # never pad past a sliding window; a prompt longer than the
            # window prefills at its exact length (its own tail legally
            # wraps the ring, exactly as the gold one-shot prefill does).
            pp = n if n > self._ring_min else min(pp, self._ring_min)
        return pp

    def _free_slots(self):
        return [s for s in range(self.slots)
                if self._slot_req[s] is None and s not in self._reserved]

    def _pager_of(self, slot: int):
        """(PagedCacheManager, manager row) for an engine slot — (None,
        slot) when the engine is dense.  The manager is engine-global
        (rows = global slot ids) in BOTH monolithic and plan mode."""
        return self._pager, slot

    def _full_init(self):
        """A fresh full-width cache (batch axis = all slots): the paged
        pool sized to the engine-global total (one physical pool,
        whatever the replica layout)."""
        if self.paged:
            return self.model.init_paged_cache(
                self.slots, self.max_seq, page_size=self.page_size,
                num_blocks=self._total_blocks, kv_dtype=self.kv_dtype)
        return self.model.init_cache(self.slots, self.max_seq)

    def _replica_views(self, plan, full):
        """Split a full-width cache into per-replica views: dense leaves
        slice on the slot axis, paged pool leaves pass through — every
        view fronts the SAME physical pool."""
        from repro.models import transformer as T
        return [T.slice_cache_slots(full, a, b - a)
                for a, b in (plan.replica_range(r)
                             for r in range(plan.n_replicas))]

    def _share_pool(self, r: int):
        """Re-alias every other replica cache's pool leaves to replica
        ``r``'s, which a step just rebound (the step donated the shared
        pool buffers it consumed).  Host-side pytree restructuring only —
        this is what keeps N replica views fronting one physical pool."""
        if not self.paged or self.plan is None:
            return
        from repro.models import transformer as T
        src = self._caches[r]
        for i in range(len(self._caches)):
            if i != r:
                self._caches[i] = T.rebind_pool_leaves(self._caches[i], src)

    def _runtime_for(self, splan):
        """The (cached) PlanRuntime for a ServingPlan — re-planning back
        to a previously-seen design point reuses its compiled fns."""
        rt = self._rt_cache.get(splan)
        if rt is None:
            from repro.plan.serving import PlanRuntime
            rt = PlanRuntime(self.model, splan, self.max_seq)
            self._rt_cache[splan] = rt
        return rt

    def _pick_slot(self, free):
        """Admission slot choice.  Monolithic engines take the first free
        slot; plan mode picks a free slot on the least-loaded replica so
        admissions spread over the spatial decode replicas (the same
        balance objective ``_rebalance_slots`` restores after a
        re-plan).  The shared pool makes the outcome otherwise
        slot-independent."""
        if self.plan is None:
            return free[0]
        load = [0] * self.plan.n_replicas
        for s in range(self.slots):
            if self._slot_req[s] is not None or s in self._reserved:
                load[self.plan.replica_of_slot(s)[0]] += 1
        return min(free,
                   key=lambda s: (load[self.plan.replica_of_slot(s)[0]], s))

    def _admit(self):
        while self.queue:
            req = self.queue[0]
            free = self._free_slots()
            if not free:
                return
            slot = self._pick_slot(free)
            ok = (self._admit_one_plan(req, slot)
                  if self.plan is not None else self._admit_one(req, slot))
            if not ok:
                return    # head-of-line waits for pool blocks (stays FIFO)
            self.queue.pop(0)

    # ---- monolithic admission (no plan) ----------------------------------
    def _admit_one(self, req: Request, slot: int) -> bool:
        """Prefill ONE request into ONE free slot: O(prompt) compute, no
        other slot's cache row or position is touched.  Returns False when
        a paged pool cannot supply the prompt's blocks yet.

        Paged admission is suffix-only: the pager reports how many prefix
        tokens are already sitting in warm registry blocks
        (``AdmitPlan.reused_tokens``) and the prefill runs over just the
        remaining suffix, writing fresh pages through ``write_table`` and
        attending the warm ones through ``block_table``.  Cold prompts
        are the reused=0 special case of the same path."""
        plen = len(req.prompt)
        if self._pager is not None:
            # speculation headroom: a verify window may write K positions
            # past the plain-decode frontier before rolling back, so the
            # growth reservation covers them
            ap = self._pager.admit(slot, req.prompt,
                                   req.max_new_tokens + self._spec_k,
                                   reuse_compute=self._suffix_reuse)
            if ap is None:
                return False
            reused = ap.reused_tokens
            suffix = req.prompt[reused:]
            slen = len(suffix)
            toks = np.zeros((1, self._padded_len(slen)), np.int32)
            toks[0, :slen] = suffix
            t0 = time.perf_counter()
            nxt, self._cache = self._prefill_suffix_paged(
                self.params, self._cache, jnp.asarray(toks),
                jnp.int32(slot), jnp.int32(reused), jnp.int32(slen),
                jnp.asarray(ap.block_table)[None],
                jnp.asarray(ap.write_table)[None])
            self._pager.commit(slot)      # pages landed: publish for reuse
            tok = int(self._sync(nxt)[0])  # host sync: prefill has run
            self._prefill_window += time.perf_counter() - t0
            if self._tr is not None:
                self._tr.span(("stage", 0), "prefill", t0, args={
                    "uid": req.uid, "slot": slot, "tokens": slen,
                    "reused": reused, "plan": "mono"})
        else:
            toks = np.zeros((1, self._padded_len(plen)), np.int32)
            toks[0, :plen] = req.prompt
            t0 = time.perf_counter()
            nxt, self._cache = self._prefill_slot(
                self.params, self._cache, jnp.asarray(toks),
                jnp.int32(slot), jnp.int32(plen))
            tok = int(self._sync(nxt)[0])  # host sync: prefill has run
            self._prefill_window += time.perf_counter() - t0
            if self._tr is not None:
                self._tr.span(("stage", 0), "prefill", t0, args={
                    "uid": req.uid, "slot": slot, "tokens": plen,
                    "reused": 0, "plan": "mono"})
        self.prefill_batch_sizes.append(1)
        # unpadded suffix tokens, same unit as plan-mode admission:
        # bucket padding is a jit-shape artifact, not prefill work
        self.prefill_token_counts.append(
            slen if self._pager is not None else plen)
        self.prefill_chunk_counts.append(1)
        self._activate(req, slot, tok)
        return True

    # ---- plan-driven admission (chunked prefill as plan stages) ----------
    def _admit_one_plan(self, req: Request, slot: int) -> bool:
        """Reserve the slot and enter the chunked-prefill pipeline: the
        prompt streams through the plan's stage slices one stage-step per
        tick (``PrefillPipeline``), so admission never stalls decode.
        Paged replicas reserve the prompt's pool blocks up front (the
        scatter at finish must not fail mid-flight)."""
        replica, local = self.plan.replica_of_slot(slot)
        reused = 0
        if self._pager is not None:
            ap = self._pager.admit(slot, req.prompt,
                                   req.max_new_tokens + self._spec_k,
                                   reuse_compute=self._suffix_reuse)
            if ap is None:
                return False
            self._admit_plans[slot] = ap
            reused = ap.reused_tokens
            self._reserved.add(slot)
            self._pf.admit(req, slot, replica, local, reused=reused,
                           tables=(ap.block_table, ap.write_table))
        else:
            self._reserved.add(slot)
            self._pf.admit(req, slot, replica, local)
        self.prefill_batch_sizes.append(1)
        self.prefill_token_counts.append(len(req.prompt) - reused)
        self.prefill_chunk_counts.append(
            len(self._pf.items[-1].chunks))
        return True

    def _chunk_committed(self, slot: int, tokens_done: int):
        """A prefill chunk left the last stage with its pool pages
        written: publish the slot's newly-completed blocks for prefix
        reuse now, without waiting for the whole admission to finish."""
        pager, local = self._pager_of(slot)
        if pager is not None:
            pager.commit_chunk(local, tokens_done)
            if self._tr is not None:
                self._tr.instant("requests", "commit", args={
                    "slot": slot, "tokens_done": tokens_done})

    def _finish_prefill(self, item):
        """Last chunk left the last stage: bank the first token, scatter
        the request's batch-1 DENSE leaves (SSM state, ring caches) into
        its replica's slot partition — the paged K/V already streamed
        into the shared pool as the chunks ran — and start decoding.

        The scatter targets the CURRENT binding: after a re-plan the
        drained item's chunks ran on its admission runtime
        (drain-and-rebind), but the finished slot lands wherever the slot
        lives now (a new replica partition, or the monolithic cache)."""
        nxt, _ = (item.rt or self._rt).finish(self.params, item.final_hidden)
        tok = int(self._sync(nxt)[0])     # host sync: prefill has run
        from repro.models import transformer as T
        if self.plan is not None:
            replica, local = self.plan.replica_of_slot(item.slot)
            if self.paged:
                self._admit_plans.pop(item.slot, None)
                self._caches[replica] = self._scatter_paged(
                    self._caches[replica], item.part_cache,
                    jnp.int32(local))
                self._share_pool(replica)
                self._pager.commit(item.slot)
            else:
                self._caches[replica] = T.scatter_cache_slot(
                    self._caches[replica], item.part_cache,
                    jnp.int32(local))
        elif self.paged:
            self._admit_plans.pop(item.slot, None)
            self._cache = self._scatter_paged(
                self._cache, item.part_cache, jnp.int32(item.slot))
            self._pager.commit(item.slot)
        else:
            self._cache = T.scatter_cache_slot(
                self._cache, item.part_cache, jnp.int32(item.slot))
        self._reserved.discard(item.slot)
        self._activate(item.req, item.slot, tok)

    def _activate(self, req: Request, slot: int, first_token: int):
        req.slot = slot
        req.t_first = time.perf_counter()
        if self._tr is not None:
            # zero-width admit marker carrying the request flow start:
            # the arrow leaves here and lands on the retire marker
            self._tr.span("requests", "admit", req.t_first, req.t_first,
                          args={"uid": req.uid, "slot": slot,
                                "queued_s": req.t_first - req.t_submit},
                          flow_out=req.uid)
        req.out_tokens.append(first_token)
        self._slot_req[slot] = req
        self._pos[slot] = len(req.prompt)
        self._cur[slot, 0] = req.out_tokens[-1]
        self._cur_known[slot] = True
        if self._overlap and self._cur_dev is not None:
            # patch the fresh slot's input token into the device-side
            # token chain (the other slots' entries are the undrained
            # previous step's outputs — they must not round-trip here)
            self._cur_dev = self._cur_dev.at[slot, 0].set(
                jnp.int32(first_token))
        self._maybe_retire(slot, req.t_first)

    # ---- decode ----------------------------------------------------------
    def _prepare_paged_writes(self, first: int, last: int):
        """Before a decode step: make every active slot's target block
        writable — allocate at page boundaries, copy-on-write shared or
        registered blocks (the device page copy runs here, before the
        step's write lands)."""
        for slot in range(first, last):
            if self._slot_req[slot] is None:
                continue
            cow = self._pager.prepare_decode(slot, int(self._pos[slot]))
            if cow is not None:
                src, dst = cow
                if self.plan is None:
                    self._cache = self._copy_pages(
                        self._cache, jnp.int32(src), jnp.int32(dst))
                else:
                    r, _ = self.plan.replica_of_slot(slot)
                    self._caches[r] = self._copy_pages(
                        self._caches[r], jnp.int32(src), jnp.int32(dst))
                    self._share_pool(r)

    def _note_decode_util(self):
        """Per-replica occupied/capacity slot-step accounting at decode
        dispatch (always on — integer adds).  Mono counts as replica 0
        over all slots.  Returns {replica: occupied_slots} for reuse by
        the trace spans."""
        if self.plan is None:
            act = self.active
            self._replica_busy[0] = self._replica_busy.get(0, 0) + act
            self._replica_cap[0] = self._replica_cap.get(0, 0) + self.slots
            return {0: act}
        out = {}
        for r in range(self.plan.n_replicas):
            a, b = self.plan.replica_range(r)
            act_r = sum(self._slot_req[s] is not None for s in range(a, b))
            self._replica_busy[r] = self._replica_busy.get(r, 0) + act_r
            self._replica_cap[r] = self._replica_cap.get(r, 0) + (b - a)
            out[r] = act_r
        return out

    def _decode_once(self):
        """One batched decode step at per-slot positions.  Idle slots ride
        along at fixed shape (their rows are garbage until the admission
        scatter replaces the whole slot; paged idle slots carry unmapped
        block tables, so their page writes drop).  Plan mode decodes each
        spatial replica independently (its slot partition, its stage
        walk).

        With speculation on, a tick whose drafter finds something runs a
        batched VERIFY step instead: every slot scores a (K+1)-token
        window (current token + drafts) in one step, commits the
        accepted prefix, and rolls the rejected tail back."""
        act = self.active                 # sampled at dispatch (see init)
        racts = self._note_decode_util()
        if self._spec_k:
            drafts = self._draft_all()
            if drafts is not None:
                self._decode_verify(drafts)
                self.decode_steps += 1
                self._decode_slot_steps += act
                self._occupied_step_sum += self.active
                return
        tr = self._tr
        if self.plan is None:
            td = time.perf_counter() if tr is not None else 0.0
            bt = None
            if self._pager is not None:
                self._prepare_paged_writes(0, self.slots)
                bt = jnp.asarray(self._pager.table_matrix())
            nxt, _, self._cache = self.serve_step(
                self.params, self._cache, jnp.asarray(self._cur),
                jnp.asarray(self._pos), None, bt)
            arr = self._sync(nxt)
            if tr is not None:
                tr.span(("replica", 0), "decode", td, args={
                    "active": act, "slots": self.slots, "plan": "mono"})
            now = time.perf_counter()
            self._collect_decoded(arr, 0, self.slots, now)
        else:
            # dispatch every replica's step before syncing any result —
            # the replicas are independent, so their device computations
            # may overlap; only then round-trip the tokens to the host.
            # (Paged replicas chain through the shared pool: each step
            # consumes the pool leaves the previous one produced — the
            # rebinding in _share_pool, serialized by data dependency.)
            pending = []
            for r in range(self.plan.n_replicas):
                a, b = self.plan.replica_range(r)
                if not any(self._slot_req[s] is not None
                           for s in range(a, b)):
                    continue
                td = time.perf_counter() if tr is not None else 0.0
                bt = None
                if self.paged:
                    self._prepare_paged_writes(a, b)
                    bt = jnp.asarray(self._pager.table_matrix()[a:b])
                nxt, self._caches[r] = self._rt.decode_step(
                    self.params, self._caches[r],
                    jnp.asarray(self._cur[a:b]),
                    jnp.asarray(self._pos[a:b]), bt)
                self._share_pool(r)
                pending.append((nxt, a, b, r, td))
            arrs = []
            for nxt, a, b, r, td in pending:
                arr = self._sync(nxt)
                if tr is not None:
                    tr.span(("replica", r), "decode", td, args={
                        "active": racts.get(r, 0), "slots": b - a,
                        "plan": self.plan.label})
                arrs.append((arr, a, b))
            now = time.perf_counter()
            for arr, a, b in arrs:
                self._collect_decoded(arr, a, b, now)
        self.decode_steps += 1
        self._decode_slot_steps += act
        self._occupied_step_sum += self.active

    # ---- overlapped (async) decode ---------------------------------------
    def _dispatch_decode(self):
        """Overlap mode: dispatch one batched decode step WITHOUT reading
        its result back.  Inputs come from ``_cur_dev`` — the previous
        step's output array, still on device — so the host never blocks
        between steps; positions and page bookkeeping advance at
        dispatch.

        A slot the pending drain is about to retire (EOS/budget known
        only once step N is read back) rides along one extra step.  That
        garbage write is safe: ``prepare_decode`` made its target block
        exclusively owned, a retired slot's blocks free only AFTER this
        dispatch (so nothing else maps them yet), and every later cache
        op is serialized behind this step by the cache's data dependency
        — any re-used page is re-written by its new owner's prefill or
        masked until its new owner's own frontier reaches it.  The
        record entry is skipped as stale at drain."""
        act = self.active
        racts = self._note_decode_util()
        tr = self._tr
        if self._cur_dev is None:
            self._cur_dev = jnp.asarray(self._cur)
        arrs = []
        rng = []
        if self.plan is None:
            td = time.perf_counter() if tr is not None else 0.0
            bt = None
            if self._pager is not None:
                self._prepare_paged_writes(0, self.slots)
                bt = jnp.asarray(self._pager.table_matrix())
            nxt, _, self._cache = self.serve_step(
                self.params, self._cache, self._cur_dev,
                jnp.asarray(self._pos), None, bt)
            self._cur_dev = nxt
            if tr is not None:
                tr.span(("replica", 0), "decode_dispatch", td, args={
                    "active": act, "slots": self.slots, "plan": "mono"})
            arrs.append((nxt, 0, self.slots))
            rng.append((0, self.slots))
        else:
            for r in range(self.plan.n_replicas):
                a, b = self.plan.replica_range(r)
                if not any(self._slot_req[s] is not None
                           for s in range(a, b)):
                    continue
                td = time.perf_counter() if tr is not None else 0.0
                bt = None
                if self.paged:
                    self._prepare_paged_writes(a, b)
                    bt = jnp.asarray(self._pager.table_matrix()[a:b])
                nxt, self._caches[r] = self._rt.decode_step(
                    self.params, self._caches[r], self._cur_dev[a:b],
                    jnp.asarray(self._pos[a:b]), bt)
                self._share_pool(r)
                self._cur_dev = self._cur_dev.at[a:b].set(nxt)
                if tr is not None:
                    tr.span(("replica", r), "decode_dispatch", td, args={
                        "active": racts.get(r, 0), "slots": b - a,
                        "plan": self.plan.label})
                arrs.append((nxt, a, b))
                rng.append((a, b))
        entries = []
        in_toks: Dict[int, Optional[int]] = {}
        for a, b in rng:
            for slot in range(a, b):
                req = self._slot_req[slot]
                if req is None:
                    continue
                entries.append((slot, req, int(self._pos[slot])))
                # the step's input token: host-known right after
                # activation, else it is the undrained previous step's
                # output — filled at that step's drain
                in_toks[slot] = (int(self._cur[slot, 0])
                                 if self._cur_known[slot] else None)
                self._cur_known[slot] = False
                self._pos[slot] += 1
        self._inflight.append(_Inflight(arrs=arrs, entries=entries,
                                        in_toks=in_toks, act=act))
        self.decode_steps += 1
        self._decode_slot_steps += act

    def _drain_one(self):
        """Read back the OLDEST in-flight step and do everything the sync
        path does after a step: extend block chains with the step's input
        tokens, append the output tokens, retire EOS/budget slots (one
        tick later than sync mode — the per-request token STREAMS are
        identical), and hand each drained token to the next in-flight
        record, whose input it is."""
        td = time.perf_counter() if self._tr is not None else 0.0
        rec = self._inflight.pop(0)
        arrs = [(self._sync(nxt), a, b) for nxt, a, b in rec.arrs]
        now = time.perf_counter()
        nxt_rec = self._inflight[0] if self._inflight else None
        nxt_req = ({s: r for s, r, _ in nxt_rec.entries}
                   if nxt_rec is not None else {})
        for arr, a, b in arrs:
            for slot, req, pos_snap in rec.entries:
                if not (a <= slot < b):
                    continue
                if self._slot_req[slot] is not req:
                    continue      # slot retired mid-flight: garbage step
                pager, local = self._pager_of(slot)
                if pager is not None:
                    tok_in = rec.in_toks[slot]
                    assert tok_in is not None, slot
                    pager.note_written(local, tok_in, pos_snap)
                tok = int(arr[slot - a, 0])
                req.out_tokens.append(tok)
                self._cur[slot, 0] = tok
                self._cur_known[slot] = True
                if nxt_req.get(slot) is req:
                    nxt_rec.in_toks[slot] = tok
                self.decode_tokens += 1
                self._maybe_retire(slot, now, pos=pos_snap + 1)
        self._occupied_step_sum += self.active
        if self._tr is not None:
            self._tr.span("tick", "drain", td, args={
                "slots_drained": len(rec.entries),
                "inflight": len(self._inflight)})

    # ---- speculative decode ----------------------------------------------
    def _draft_all(self):
        """Prompt-lookup drafts for every active slot, or None when this
        tick should run plain decode instead (no slot drafted anything,
        or some active slot's window would run past the slot cache).  A
        slot's draft is clamped so accepted tokens can never overshoot
        its remaining budget."""
        K = self._spec_k
        drafts: Dict[int, List[int]] = {}
        any_draft = False
        for slot in range(self.slots):
            req = self._slot_req[slot]
            if req is None:
                continue
            if int(self._pos[slot]) + K > self.max_seq - 2:
                # the window would write past the last position the gold
                # decode ever writes (max_seq - 2): plain-decode this tick
                return None
            # accepting all K drafts emits K+1 tokens: cap at the budget
            budget = min(K, req.max_new_tokens - len(req.out_tokens) - 1)
            ctx = np.concatenate([req.prompt,
                                  np.asarray(req.out_tokens, np.int32)])
            d = ngram_draft(ctx, budget)
            drafts[slot] = d
            any_draft = any_draft or bool(d)
        return drafts if any_draft else None

    def _prepare_verify_writes(self, first: int, last: int, sw: int):
        """Before a verify step: make every window position's block
        writable for every active slot (boundary blocks allocate, a
        shared or registered block copy-on-writes — only possible at the
        first window position; the rest of the window grows fresh
        blocks)."""
        for slot in range(first, last):
            if self._slot_req[slot] is None:
                continue
            pos = int(self._pos[slot])
            for j in range(sw):
                cow = self._pager.prepare_decode(slot, pos + j)
                if cow is not None:
                    src, dst = cow
                    if self.plan is None:
                        self._cache = self._copy_pages(
                            self._cache, jnp.int32(src), jnp.int32(dst))
                    else:
                        r, _ = self.plan.replica_of_slot(slot)
                        self._caches[r] = self._copy_pages(
                            self._caches[r], jnp.int32(src), jnp.int32(dst))
                        self._share_pool(r)

    def _decode_verify(self, drafts: Dict[int, List[int]]):
        """One speculative tick: write + score each slot's (K+1)-token
        window in a single batched step, then accept the longest prefix
        of drafts the target model agrees with (greedy verify), roll the
        rejected tail back, and continue from the last accepted token.
        Slots that drafted nothing degenerate to plain decode (their
        window is just the current token plus ignored padding)."""
        sw = self._spec_k + 1
        window = np.zeros((self.slots, sw), np.int32)
        window[:, 0] = self._cur[:, 0]
        for slot, d in drafts.items():
            if d:
                window[slot, 1:1 + len(d)] = d
        tr = self._tr
        if self.plan is None:
            td = time.perf_counter() if tr is not None else 0.0
            bt = None
            if self._pager is not None:
                self._prepare_verify_writes(0, self.slots, sw)
                bt = jnp.asarray(self._pager.table_matrix())
            outs, self._cache = self._verify_step(
                self.params, self._cache, jnp.asarray(window),
                jnp.asarray(self._pos), bt)
            arr0 = self._sync(outs)
            if tr is not None:
                tr.span(("replica", 0), "verify", td, args={
                    "window": sw, "drafted": sum(map(len, drafts.values())),
                    "plan": "mono"})
            now = time.perf_counter()
            self._collect_verified(window, arr0, drafts,
                                   0, self.slots, now)
        else:
            pending = []
            for r in range(self.plan.n_replicas):
                a, b = self.plan.replica_range(r)
                if not any(self._slot_req[s] is not None
                           for s in range(a, b)):
                    continue
                td = time.perf_counter() if tr is not None else 0.0
                bt = None
                if self.paged:
                    self._prepare_verify_writes(a, b, sw)
                    bt = jnp.asarray(self._pager.table_matrix()[a:b])
                outs, self._caches[r] = self._rt.verify_step(
                    self.params, self._caches[r],
                    jnp.asarray(window[a:b]),
                    jnp.asarray(self._pos[a:b]), bt)
                self._share_pool(r)
                pending.append((outs, a, b, r, td))
            arrs = []
            for o, a, b, r, td in pending:
                arr = self._sync(o)
                if tr is not None:
                    tr.span(("replica", r), "verify", td, args={
                        "window": sw, "plan": self.plan.label})
                arrs.append((arr, a, b))
            now = time.perf_counter()
            for arr, a, b in arrs:
                self._collect_verified(window, arr, drafts, a, b, now)
        self.spec_steps += 1

    def _collect_verified(self, window, outs, drafts, a: int, b: int,
                          now: float):
        """Greedy acceptance per slot: position j's argmax is what a
        sequential decode would emit after consuming window[0..j], so the
        emitted prefix extends exactly while each argmax equals the next
        drafted token (and is not EOS) — accepted streams are therefore
        bit-identical to the one-shot greedy stream."""
        for slot in range(a, b):
            req = self._slot_req[slot]
            if req is None:
                continue
            d = drafts.get(slot, [])
            pos = int(self._pos[slot])
            row = outs[slot - a]
            emitted: List[int] = []
            j = 0
            while True:
                tok = int(row[j])
                emitted.append(tok)
                if req.eos_token is not None and tok == req.eos_token:
                    break
                if j < len(d) and int(window[slot, j + 1]) == tok:
                    j += 1
                else:
                    break
            m = len(emitted)
            pager, local = self._pager_of(slot)
            if pager is not None:
                # the step wrote the window's K/V at pos..pos+K: keep the
                # accepted inputs' chain, then roll the rejected tail back
                for i in range(m):
                    pager.note_written(local, int(window[slot, i]), pos + i)
                pager.rollback(local, pos + m)
            self._pos[slot] = pos + m    # dense engines just rewind here
            req.out_tokens.extend(emitted)
            self._cur[slot, 0] = emitted[-1]
            self.decode_tokens += m
            self.spec_proposed += len(d)
            self.spec_accepted += m - 1
            self._maybe_retire(slot, now)

    def _collect_decoded(self, arr, a: int, b: int, now: float):
        for slot in range(a, b):
            req = self._slot_req[slot]
            if req is None:
                continue
            pager, local = self._pager_of(slot)
            if pager is not None:
                # the step wrote this slot's INPUT token's K/V at _pos:
                # extend the block chain (registers blocks as they fill)
                pager.note_written(local, int(self._cur[slot, 0]),
                                   int(self._pos[slot]))
            self._pos[slot] += 1
            tok = int(arr[slot - a, 0])
            req.out_tokens.append(tok)
            self._cur[slot, 0] = tok
            self.decode_tokens += 1
            self._maybe_retire(slot, now)

    def _maybe_retire(self, slot: int, now: float,
                      pos: Optional[int] = None):
        """Slot-level retirement: EOS, token budget, or a full slot cache.
        Only this slot frees — every other slot keeps decoding.  Paged
        engines release the slot's blocks; fully-released registered
        blocks park in the pool's LRU for prefix reuse.

        ``pos``: the slot's written-token count as of the step being
        accounted — overlap drains pass it explicitly because
        ``self._pos`` has already advanced past it for the next,
        still-in-flight dispatch."""
        if pos is None:
            pos = int(self._pos[slot])
        req = self._slot_req[slot]
        if (len(req.out_tokens) >= req.max_new_tokens
                or (req.eos_token is not None
                    and req.out_tokens[-1] == req.eos_token)
                or pos >= self.max_seq - 1):
            req.t_done = now
            self.done.append(req)
            self._slot_req[slot] = None
            pager, local = self._pager_of(slot)
            if pager is not None:
                pager.release_slot(local)
            # live request metrics (always on): explicit-bucket TTFT/TPOT
            # histograms + retirement counters
            self._h_ttft.observe(req.t_first - req.t_submit)
            n = max(len(req.out_tokens) - 1, 1)
            self._h_tpot.observe((req.t_done - req.t_first) / n)
            self._c_requests.inc()
            self._c_gen.inc(len(req.out_tokens))
            if self._tr is not None:
                self._tr.span("requests", "retire", now, now, args={
                    "uid": req.uid, "slot": slot,
                    "tokens": len(req.out_tokens),
                    "latency_s": req.t_done - req.t_submit},
                    flow_in=req.uid)
