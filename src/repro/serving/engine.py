"""Batched serving engine: prefill + decode with a slot-based continuous
batching scheduler (vLLM-lite).

``serve_step`` — the function the decode-shape dry-runs lower — is one
batched decode step over a fixed slot set.  The ``ServingEngine`` drives it:
requests occupy slots, finished slots are refilled from the queue, so the
batch stays full (the serving-side utilization knob the paper's throughput
story depends on).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.backend import dispatch
from repro.models.model import Model


def make_serve_step(model: Model):
    """serve_step(params, cache, tokens, cache_index) ->
    (next_tokens, logits, new_cache) — one greedy decode step."""

    def serve_step(params, cache, tokens, cache_index, positions=None):
        logits, cache = model.decode_step(params, cache, tokens, cache_index,
                                          positions=positions)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], logits, cache

    return serve_step


def make_prefill_step(model: Model, max_seq: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_seq)
    return prefill_step


@dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (prompt_len,)
    max_new_tokens: int
    out_tokens: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


@dataclass
class ServingEngine:
    """Fixed-slot continuous batching over a single shared max_seq cache."""
    model: Model
    params: Any
    slots: int
    max_seq: int

    def __post_init__(self):
        self.cfg = self.model.cfg
        # the engine's prefill/decode steps execute their hot kernels via
        # the dispatch front door (repro.backend.dispatch) inside the model;
        # record the resolved path so serving stats name what actually ran.
        self.kernel_path = dispatch.kernel_path()
        self.serve_step = jax.jit(make_serve_step(self.model))
        self._decode_one = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.max_seq))
        self.queue: List[Request] = []
        self.done: List[Request] = []

    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def run(self, max_steps: int = 10_000):
        """Simple loop: (re)fill slots via per-slot prefill, then batched
        decode steps until all requests finish."""
        while self.queue or getattr(self, "_active", None):
            self._fill_slots()
            if not self._active:
                break
            self._decode_burst(max_steps)
        return self.done

    # -- internals --------------------------------------------------------
    def _fill_slots(self):
        self._active: List[Request] = getattr(self, "_active", [])
        while self.queue and len(self._active) < self.slots:
            req = self.queue.pop(0)
            self._active.append(req)
        if not self._active:
            return
        # batch prefill (pad to same prompt len)
        plen = max(len(r.prompt) for r in self._active)
        B = len(self._active)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(self._active):
            toks[i, plen - len(r.prompt):] = r.prompt
        logits, cache = self._decode_one(self.params, {"tokens": jnp.asarray(toks)})
        self._cache = cache
        self._pos = plen
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1)).astype(np.int32)
        now = time.perf_counter()
        for i, r in enumerate(self._active):
            r.out_tokens.append(int(nxt[i]))
            r.t_first = now
        self._cur = nxt[:, None]

    def _decode_burst(self, max_steps: int):
        steps = 0
        while self._active and steps < max_steps:
            nxt, _, self._cache = self.serve_step(
                self.params, self._cache, jnp.asarray(self._cur),
                jnp.int32(self._pos))
            self._pos += 1
            steps += 1
            arr = np.asarray(nxt)
            still = []
            now = time.perf_counter()
            for i, r in enumerate(self._active):
                r.out_tokens.append(int(arr[i, 0]))
                if len(r.out_tokens) >= r.max_new_tokens \
                        or self._pos >= self.max_seq - 1:
                    r.t_done = now
                    self.done.append(r)
                else:
                    still.append(r)
            if len(still) != len(self._active):
                # slots freed: return to fill (simplified: finish burst)
                self._active = still
                break
            self._active = still
            self._cur = arr
