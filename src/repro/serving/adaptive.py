"""Online Pareto navigation: traffic-adaptive re-plan control.

The DSE hands serving a *set* of design points — the monolithic engine
(one dispatch per phase, the latency end) and ``ServingPlan``s of varying
spatial decode width / chunked-prefill depth (the throughput end).  Under
live traffic no single point dominates: near-idle, the monolithic step
wins (one jitted dispatch serves every slot, and nothing queues behind a
prompt); under prompt bursts, the pipelined plan wins (chunked prefill
interleaves with decode, so TTFT does not stall behind whole-prompt
admissions).  ``ReplanController`` watches a rolling traffic window and
walks the engine along that Pareto front at runtime via
``ServingEngine.replan`` — zero-copy on the paged path (slot state moves
by block-table handoff, never by KV copy).

Signals (sampled every tick, decided every ``interval_ticks``):

  * arrival rate / prompt length / requested tokens over ``window_s``
    (from the engine's arrival log);
  * queued prompt tokens and active decode depth (live backlog);
  * observed TTFT of recently finished requests and the live
    head-of-queue wait, against the SLO targets.

Cost model (host-serial, matching how the interpreter actually runs):
each candidate's measured unit times (``plan.validate
.measure_serving_stage_times`` for plans, a mono probe here) price the
current backlog plus ``horizon_s`` of forecast arrivals.  The monolithic
point serializes prefill before decode resumes; a plan overlaps them but
pays every replica's decode dispatch per tick.  The candidate with the
lowest SLO-penalized makespan wins; ``hysteresis`` keeps the controller
from flapping between near-equal points (dropped to zero while an SLO is
being violated) and ``cooldown_ticks`` spaces consecutive swaps.

Degenerate case: when the best candidate IS the current plan, the
decision is still useful — ``replan(current)`` re-balances active slots
across the decode replicas (cross-replica work stealing).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class PlanProfile:
    """Unit times of one design point, normalized so mono and plan
    candidates price through the same formulas.

      * ``prefill_tok_s`` — steady-state seconds per backlog prefill
        token (mono: the whole-prompt rate; plan: pipeline-bottleneck
        stage time / chunk);
      * ``first_latency_s`` — extra latency of a prompt's own first
        token beyond the backlog rate (plan: one full stage walk of its
        first chunk; mono: 0 — the backlog rate already prices it);
      * ``decode_tick_s`` — host-serial decode cost per engine tick
        (mono: one full-batch dispatch; plan: every replica's dispatch);
      * ``interfere_s`` — prefill work a decode tick waits behind while
        prompts are streaming (mono: a whole admission; plan: roughly
        one stage-step).
    """
    prefill_tok_s: float
    first_latency_s: float
    decode_tick_s: float
    interfere_s: float
    chunk: int
    is_plan: bool
    measured: bool


@dataclass
class AdaptiveConfig:
    """Knobs for ``ReplanController``; pass as ``ServingEngine(adapt=...)``.

    ``plans`` lists the candidate design points: ``ServingPlan``s and/or
    ``None`` for the monolithic engine.  The engine's initial plan is
    added automatically if missing.  ``slo_ttft_s`` / ``slo_tpot_s`` of 0
    disable that SLO term.  ``measure=False`` skips the timing probes and
    prices candidates with an analytic group-count profile (deterministic
    — useful for tests)."""
    plans: Sequence[Any] = field(default_factory=list)
    slo_ttft_s: float = 0.0
    slo_tpot_s: float = 0.0
    window_s: float = 2.0
    interval_ticks: int = 8
    hysteresis: float = 0.25
    cooldown_ticks: int = 32
    measure: bool = True
    horizon_s: float = 0.5


def measure_mono_step_times(model, params, slots: int, max_seq: int, *,
                            repeat: int = 3) -> Dict[str, float]:
    """Timing probe for the monolithic design point: seconds per prefill
    token (one whole-prompt slot admission) and per full-batch decode
    step.  Uses throwaway dense caches and NON-donating jits, so live
    engine state is never touched; compile time is excluded."""
    from repro.serving.engine import make_prefill_slot_step, make_serve_step
    serve = jax.jit(make_serve_step(model))
    prefill = jax.jit(make_prefill_slot_step(model, max_seq))
    cache = model.init_cache(slots, max_seq)
    P = max(4, min(32, max_seq - 1))
    toks = jnp.zeros((1, P), jnp.int32)

    def _timed(fn):
        jax.block_until_ready(fn())           # compile outside the clock
        t0 = time.perf_counter()
        for _ in range(repeat):
            jax.block_until_ready(fn())
        return (time.perf_counter() - t0) / repeat

    pref_s = _timed(lambda: prefill(params, cache, toks,
                                    jnp.int32(0), jnp.int32(P))[0])
    dtoks = jnp.zeros((slots, 1), jnp.int32)
    dpos = jnp.zeros((slots,), jnp.int32)
    dec_s = _timed(lambda: serve(params, cache, dtoks, dpos)[0])
    return {"prefill_tok_s": pref_s / P, "decode_step_s": dec_s}


class ReplanController:
    """Rolling-window traffic watcher + windowed cost model deciding when
    ``ServingEngine`` should swap design points.  ``observe(engine)`` is
    called at the top of every tick; it returns ``None`` (keep the
    current binding) or a 1-tuple ``(plan,)`` naming the new binding
    (``(None,)`` = go monolithic — the tuple disambiguates "no decision"
    from "decide mono")."""

    def __init__(self, cfg: AdaptiveConfig):
        self.cfg = cfg
        self.paused = False           # warm_replans() sets this while it
        #                               drives candidates through the engine
        self._profiles: Dict[Any, PlanProfile] = {}
        self._ticks = 0
        self._cooldown = 0
        self.decisions: List[Tuple[int, str, str]] = []   # (tick, from, to)
        self.last_scores: Optional[List[List[Any]]] = None  # [[label,
        #                               score], ...] of the most recent
        #                               SCORING tick (None between
        #                               decision windows) — the engine
        #                               attaches this to its
        #                               "replan_decision" trace event

    # ------------------------------------------------------------ set-up
    def validate(self, eng) -> None:
        """Sanity-check the candidate ladder against the engine (called
        from ``ServingEngine.__post_init__``)."""
        self.cfg.plans = list(self.cfg.plans)
        for cand in self.cfg.plans:
            if cand is not None and cand.slots != eng.slots:
                raise ValueError(
                    f"adaptive candidate {cand.label!r} was lowered for "
                    f"{cand.slots} slots but the engine has {eng.slots}; "
                    f"re-lower via lower_serving(plan, slots={eng.slots}) "
                    f"or rereplicate_serving")
        if not any(cand == eng.plan for cand in self.cfg.plans):
            self.cfg.plans.insert(0, eng.plan)
        if len(self.cfg.plans) < 2:
            only = self.cfg.plans[0]
            if only is None or only.n_replicas < 2:
                raise ValueError(
                    "adaptive serving needs >= 2 candidate design points "
                    "(AdaptiveConfig.plans plus the engine's initial "
                    "plan), or a single multi-replica plan (the "
                    "degenerate case: cross-replica work stealing only)")

    def warm(self, eng) -> None:
        """Measure every candidate's profile up front (otherwise the
        first decision tick pays for it inside the serving window)."""
        for cand in self.cfg.plans:
            self._profile(eng, cand)

    # ----------------------------------------------------------- profiles
    def _profile(self, eng, cand) -> PlanProfile:
        prof = self._profiles.get(cand)
        if prof is None:
            prof = (self._measure(eng, cand) if self.cfg.measure
                    else self._analytic(eng, cand))
            self._profiles[cand] = prof
        return prof

    def _measure(self, eng, cand) -> PlanProfile:
        if cand is None:
            t = measure_mono_step_times(eng.model, eng.params, eng.slots,
                                        eng.max_seq)
            return PlanProfile(
                prefill_tok_s=t["prefill_tok_s"], first_latency_s=0.0,
                decode_tick_s=t["decode_step_s"],
                interfere_s=t["prefill_tok_s"] * 16,   # ~one admission of
                chunk=1, is_plan=False, measured=True)  # a short prompt
        from repro.plan.validate import measure_serving_stage_times
        t = measure_serving_stage_times(eng.model, eng.params, cand,
                                        eng.max_seq,
                                        runtime=eng._runtime_for(cand))
        stage_sum = float(sum(t["stage_s"]))
        stage_max = float(max(t["stage_s"]))
        return PlanProfile(
            prefill_tok_s=stage_max / max(cand.chunk, 1),
            first_latency_s=stage_sum,
            decode_tick_s=float(sum(t["decode_step_s"])),
            interfere_s=stage_sum / max(cand.n_stages, 1),
            chunk=cand.chunk, is_plan=True, measured=True)

    def _analytic(self, eng, cand) -> PlanProfile:
        """Deterministic structural profile (``measure=False``): unit cost
        per (group x token) of work, one dispatch overhead per jitted
        call.  Encodes only the host-serial shape — mono pays one
        dispatch per phase, a plan pays one per stage / per replica —
        not real silicon."""
        unit, disp = 1e-5, 1e-4
        G = max(int(getattr(eng.model.cfg, "num_groups", 1)), 1)
        if cand is None:
            return PlanProfile(
                prefill_tok_s=unit * G + disp / 16, first_latency_s=0.0,
                decode_tick_s=disp + unit * G, interfere_s=disp + 16 * unit * G,
                chunk=1, is_plan=False, measured=False)
        per_stage = [disp + unit * s.n_groups * cand.chunk
                     for s in cand.plan.stages]
        return PlanProfile(
            prefill_tok_s=max(per_stage) / cand.chunk,
            first_latency_s=sum(per_stage),
            decode_tick_s=cand.n_replicas * (disp + unit * G),
            interfere_s=sum(per_stage) / len(per_stage),
            chunk=cand.chunk, is_plan=True, measured=False)

    # ----------------------------------------------------------- decision
    def observe(self, eng) -> Optional[Tuple[Any]]:
        self._ticks += 1
        self.last_scores = None
        if self._cooldown > 0:
            self._cooldown -= 1
            return None
        if self._ticks % max(self.cfg.interval_ticks, 1):
            return None
        sig = self._signals(eng)
        if sig is None:
            return None
        scored = [(self._score(eng, cand, sig), i, cand)
                  for i, cand in enumerate(self.cfg.plans)]
        self.last_scores = [
            [cand.label if cand is not None else "mono", float(s)]
            for s, _, cand in sorted(scored)]
        cur = next(s for s, _, cand in scored if cand == eng.plan)
        best_s, _, best = min(scored)
        if best == eng.plan:
            # degenerate case: the current multi-replica plan stays, but
            # its replicas drifted out of balance (retirements land
            # unevenly) — replan(current) is pure work stealing
            if eng.plan is not None and self._imbalanced(eng):
                self._cooldown = self.cfg.cooldown_ticks
                return (eng.plan,)
            return None
        margin = 0.0 if sig.violated else self.cfg.hysteresis
        if best_s >= cur * (1.0 - margin):
            return None
        self._cooldown = self.cfg.cooldown_ticks
        self.decisions.append((
            self._ticks,
            eng.plan.label if eng.plan is not None else "mono",
            best.label if best is not None else "mono"))
        return (best,)

    def _imbalanced(self, eng) -> bool:
        plan = eng.plan
        load = [0] * plan.n_replicas
        for s in range(eng.slots):
            if eng._slot_req[s] is not None or s in eng._reserved:
                load[plan.replica_of_slot(s)[0]] += 1
        return max(load) - min(load) > 1

    def _signals(self, eng):
        """One typed ``repro.obs.TrafficSnapshot`` of the observation
        window (None = idle).  The engine owns the computation
        (``ServingEngine.traffic_snapshot``) — the controller only states
        which window/SLO parameters it observes under."""
        return eng.traffic_snapshot(
            self.cfg.window_s, slo_ttft_s=self.cfg.slo_ttft_s,
            slo_tpot_s=self.cfg.slo_tpot_s, horizon_s=self.cfg.horizon_s)

    def _score(self, eng, cand, sig) -> float:
        """SLO-penalized makespan of the backlog + ``horizon_s`` of
        forecast arrivals under candidate ``cand``, priced from a
        ``TrafficSnapshot``.  Mono serializes prefill ahead of decode; a
        plan overlaps them (max + half the smaller term) but pays every
        replica's dispatch per tick."""
        prof = self._profile(eng, cand)
        ptok = sig.queued_tok + sig.lam * self.cfg.horizon_s * sig.avg_prompt
        t_pref = ptok * prof.prefill_tok_s
        t_dec = sig.depth * prof.decode_tick_s
        if prof.is_plan:
            makespan = max(t_pref, t_dec) + 0.5 * min(t_pref, t_dec)
        else:
            makespan = t_pref + t_dec
        pen = 0.0
        if self.cfg.slo_ttft_s > 0:
            own = sig.avg_prompt * prof.prefill_tok_s \
                + prof.first_latency_s
            ttft_pred = t_pref + own
            pen += max(0.0, ttft_pred / self.cfg.slo_ttft_s - 1.0)
        if self.cfg.slo_tpot_s > 0:
            busy = min(1.0, ptok / max(prof.chunk, 1.0))
            tpot_pred = prof.decode_tick_s + busy * prof.interfere_s
            pen += max(0.0, tpot_pred / self.cfg.slo_tpot_s - 1.0)
        return makespan * (1.0 + pen)
