"""Nemotron-4-15B  [arXiv:2402.16819].

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000, squared-ReLU MLP
(non-gated), layernorm.
"""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24_576,
    vocab_size=256_000,
    block_pattern=(BlockSpec("attn", "dense"),),
    rope_theta=10_000.0,
    mlp_activation="relu2",
    gated_mlp=False,
    norm_kind="layernorm",
)
