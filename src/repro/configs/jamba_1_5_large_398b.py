"""Jamba-1.5-Large (398B total / 94B active class)  [arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.
Mamba:attention 1:7 interleave (one attention layer per 8-layer period,
position 3 inside the period, as in the released model), MoE 16 experts
top-2 on every other layer.
"""
from repro.configs.base import BlockSpec, ModelConfig, MoEConfig, SSMConfig

_PERIOD = []
for i in range(8):
    mixer = "attn" if i == 3 else "mamba"
    ffn = "moe" if i % 2 == 1 else "dense"
    _PERIOD.append(BlockSpec(mixer, ffn))

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24_576,
    vocab_size=65_536,
    block_pattern=tuple(_PERIOD),
    moe=MoEConfig(
        num_experts=16,
        experts_per_token=2,
        expert_d_ff=24_576,
    ),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    rope_theta=0.0,               # jamba attention layers use no RoPE
    mlp_activation="silu",
    norm_kind="rmsnorm",
    subquadratic=True,            # mamba-dominated: long_500k applies
)
