"""Gemma2-9B  [arXiv:2408.00118].

42L d_model=3584 16H (GQA kv=8, head_dim=256) d_ff=14336 vocab=256000.
Alternating local (sliding-window 4096) / global attention, attention and
final logit soft-capping, pre+post block rmsnorm, GeGLU.
"""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14_336,
    vocab_size=256_000,
    block_pattern=(
        BlockSpec("attn_local", "dense"),
        BlockSpec("attn_global", "dense"),
    ),
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    rope_theta=10_000.0,
    mlp_activation="gelu",
    gated_mlp=True,
    norm_kind="rmsnorm",
    post_block_norm=True,
    tie_embeddings=True,
)
