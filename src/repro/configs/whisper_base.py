"""Whisper-base  [arXiv:2212.04356].

Encoder-decoder: 6 encoder + 6 decoder layers, d_model=512 8H (kv=8)
d_ff=2048 vocab=51865.  The conv/mel frontend is a STUB — ``input_specs()``
provides precomputed frame embeddings of shape (batch, frames, d_model).
"""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,                  # decoder layers
    encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    block_pattern=(BlockSpec("attn", "dense"),),
    mlp_activation="gelu",
    gated_mlp=False,
    norm_kind="layernorm",
    frontend="audio",
    rope_theta=0.0,               # whisper uses learned/sinusoidal positions
)
