"""Base configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; every assigned
input shape as a ``ShapeConfig``.  Configs are pure data — models are built
from them by ``repro.models.model.build_model`` and meshes/shardings by
``repro.launch.mesh`` / ``repro.sharding``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block-kind vocabulary
# ---------------------------------------------------------------------------
# A layer ("block") is a (mixer, ffn) pair.  ``block_pattern`` holds one
# period of the repeating layer pattern; the full stack is
# ``num_layers // len(block_pattern)`` repetitions of it (scanned).
MIXER_KINDS = (
    "attn",          # full (causal for LM) attention
    "attn_local",    # sliding-window attention
    "attn_global",   # full attention in an alternating local/global stack
    "mamba",         # Mamba-1 selective SSM mixer
    "mlstm",         # xLSTM matrix-memory block
    "slstm",         # xLSTM scalar-memory block
)
FFN_KINDS = ("dense", "moe", "none")


@dataclass(frozen=True)
class BlockSpec:
    """One layer position inside the repeating pattern."""
    mixer: str = "attn"
    ffn: str = "dense"

    def __post_init__(self):
        assert self.mixer in MIXER_KINDS, self.mixer
        assert self.ffn in FFN_KINDS, self.ffn


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    experts_per_token: int = 2
    # Routed-expert FFN hidden dim (may differ from dense d_ff).
    expert_d_ff: int = 0
    num_shared_experts: int = 0
    shared_expert_d_ff: int = 0
    # Capacity factor for dropping-based dispatch (GShard-style).
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 mixer hyper-params (used when a block's mixer == 'mamba')."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block hyper-params (mixer in {'mlstm','slstm'})."""
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    conv_kernel: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # Repeating layer pattern (length divides num_layers).
    block_pattern: Tuple[BlockSpec, ...] = (BlockSpec(),)

    # Attention variants.
    window_size: int = 4096          # for attn_local
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # e.g. (16,24,24) for qwen2-vl
    attn_logit_softcap: float = 0.0        # 0 -> disabled
    final_logit_softcap: float = 0.0
    qk_norm: bool = False

    # FFN / norms.
    mlp_activation: str = "silu"     # silu | gelu | relu2
    gated_mlp: bool = True
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm
    post_block_norm: bool = False    # gemma2-style pre+post norms
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    ssm: SSMConfig = SSMConfig()
    xlstm: XLSTMConfig = XLSTMConfig()

    # Encoder-decoder (whisper): encoder layer count; decoder = num_layers.
    encoder_layers: int = 0
    # Modality frontend stub: inputs are precomputed embeddings of this dim
    # rather than token ids ('' = token ids).
    frontend: str = ""               # '' | 'audio' | 'vision'

    # Numerics.
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # Long-context capability: True if per-token state is O(1)/sub-quadratic
    # (SSM / hybrid) so long_500k applies.
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_layers % len(self.block_pattern) == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern length {len(self.block_pattern)}")
        assert self.num_heads % max(self.num_kv_heads, 1) == 0

    # ----- derived -----
    @property
    def num_groups(self) -> int:
        """Number of scan iterations (pattern repetitions)."""
        return self.num_layers // len(self.block_pattern)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def pattern_mixers(self) -> Tuple[str, ...]:
        return tuple(b.mixer for b in self.block_pattern)

    def has_attention(self) -> bool:
        return any(b.mixer.startswith("attn") for b in self.block_pattern)

    def is_pure_full_attention(self) -> bool:
        """True if every mixer is (possibly windowed-alternating) softmax
        attention — i.e. no O(1)-state path exists for very long context."""
        return all(b.mixer.startswith("attn") for b in self.block_pattern)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four assigned LM shapes (identical across archs).
TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """Shapes applicable to ``cfg`` (long_500k only for sub-quadratic archs;
    skips are recorded, not silently dropped — see dryrun.py)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.subquadratic:
        out.append(LONG_500K)
    return tuple(out)


def reduced(cfg: ModelConfig, *, layers: int = 0, d_model: int = 64,
            vocab: int = 256) -> ModelConfig:
    """A smoke-test-sized config of the same family: keeps one full pattern
    period (so every block kind is exercised) but tiny dims."""
    period = len(cfg.block_pattern)
    n_layers = layers or period * min(2, cfg.num_groups)
    heads = min(cfg.num_heads, 4)
    kv = max(1, min(cfg.num_kv_heads, 2))
    while heads % kv:
        kv -= 1
    head_dim = 16
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, num_experts=4,
            experts_per_token=min(cfg.moe.experts_per_token, 2),
            expert_d_ff=32,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            shared_expert_d_ff=64)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=n_layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=vocab,
        window_size=min(cfg.window_size, 16),
        encoder_layers=min(cfg.encoder_layers, 2),
        moe=moe,
        ssm=dataclasses.replace(cfg.ssm, d_state=8, d_conv=4, expand=2),
        mrope_sections=(2, 3, 3) if cfg.mrope_sections else (),  # sums hd/2
        dtype="float32",
        param_dtype="float32",
    )


SMOKE_SHAPE = ShapeConfig("smoke", 32, 2, "train")
SMOKE_DECODE = ShapeConfig("smoke_decode", 64, 2, "decode")
