"""IBM Granite-3.0-1B-A400M-base  [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) vocab=49155, MoE: 32 experts top-8 with
expert d_ff=512.
"""
from repro.configs.base import BlockSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49_155,
    block_pattern=(BlockSpec("attn", "moe"),),
    moe=MoEConfig(
        num_experts=32,
        experts_per_token=8,
        expert_d_ff=512,
    ),
    rope_theta=10_000.0,
    mlp_activation="silu",
    norm_kind="rmsnorm",
    tie_embeddings=True,
)
