"""Qwen1.5/2-MoE-A2.7B  [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) vocab=151936, MoE: 60 routed experts top-4 with
expert d_ff=1408, plus 4 shared experts (modeled as one fused shared expert of
4*1408=5632, matching the HF ``shared_expert_intermediate_size``).
"""
from repro.configs.base import BlockSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5632,                     # dense-equivalent ff (shared path)
    vocab_size=151_936,
    block_pattern=(BlockSpec("attn", "moe"),),
    moe=MoEConfig(
        num_experts=60,
        experts_per_token=4,
        expert_d_ff=1408,
        num_shared_experts=4,
        shared_expert_d_ff=5632,
    ),
    rope_theta=1_000_000.0,
    mlp_activation="silu",
    norm_kind="rmsnorm",
)
