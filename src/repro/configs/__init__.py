"""Config registry: ``get_config(name)`` / ``ARCHS`` (the 10 assigned archs)
plus the paper's own DeiT family."""
from repro.configs.base import (ALL_SHAPES, LONG_500K, SHAPES, SMOKE_DECODE,
                                SMOKE_SHAPE, BlockSpec, ModelConfig,
                                MoEConfig, ShapeConfig, SSMConfig,
                                XLSTMConfig, reduced, shapes_for)
from repro.configs.deit import DEIT_160, DEIT_256, DEIT_T, LV_VIT_T, vit_shape
from repro.configs.gemma2_9b import CONFIG as GEMMA2_9B
from repro.configs.granite_moe_1b_a400m import CONFIG as GRANITE_MOE
from repro.configs.jamba_1_5_large_398b import CONFIG as JAMBA_398B
from repro.configs.nemotron_4_15b import CONFIG as NEMOTRON_15B
from repro.configs.qwen2_moe_a2_7b import CONFIG as QWEN2_MOE
from repro.configs.qwen2_vl_72b import CONFIG as QWEN2_VL_72B
from repro.configs.whisper_base import CONFIG as WHISPER_BASE
from repro.configs.xlstm_125m import CONFIG as XLSTM_125M
from repro.configs.yi_34b import CONFIG as YI_34B
from repro.configs.yi_6b import CONFIG as YI_6B

# The 10 assigned architectures, in the assignment's order.
ARCHS = {
    c.name: c for c in (
        QWEN2_MOE, GRANITE_MOE, WHISPER_BASE, JAMBA_398B, QWEN2_VL_72B,
        YI_34B, YI_6B, NEMOTRON_15B, GEMMA2_9B, XLSTM_125M,
    )
}

# Paper models (FPGA'24 Table 3).
PAPER_MODELS = {c.name: c for c in (DEIT_T, DEIT_160, DEIT_256, LV_VIT_T)}

REGISTRY = {**ARCHS, **PAPER_MODELS}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = [
    "ARCHS", "PAPER_MODELS", "REGISTRY", "get_config", "reduced",
    "shapes_for", "vit_shape", "ALL_SHAPES", "SHAPES", "LONG_500K",
    "SMOKE_SHAPE", "SMOKE_DECODE", "ModelConfig", "ShapeConfig", "MoEConfig",
    "SSMConfig", "XLSTMConfig", "BlockSpec",
]
