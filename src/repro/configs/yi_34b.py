"""Yi-34B  [arXiv:2403.04652] — llama-arch GQA dense.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20_480,
    vocab_size=64_000,
    block_pattern=(BlockSpec("attn", "dense"),),
    rope_theta=5_000_000.0,
    mlp_activation="silu",
    norm_kind="rmsnorm",
)
