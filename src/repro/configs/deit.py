"""The paper's own models (Table 3): DeiT-T, DeiT-160, DeiT-256, LV-ViT-T.

Encoder-only vision transformers for image classification; 196 patches + 1
cls token = 197 sequence positions at 224x224/16.  These drive the paper
reproduction benchmarks (Tables 5-7, Figs 2/10).  The patch-embedding conv
is a stub (``input_specs()`` provides patch embeddings), matching how the
assigned-modality archs are handled.
"""
from repro.configs.base import BlockSpec, ModelConfig, ShapeConfig


def _vit(name, heads, dim, depth, d_ff=None):
    return ModelConfig(
        name=name,
        family="vision",
        num_layers=depth,
        d_model=dim,
        num_heads=heads,
        num_kv_heads=heads,
        d_ff=d_ff if d_ff is not None else 4 * dim,
        vocab_size=1000,              # classifier head
        block_pattern=(BlockSpec("attn", "dense"),),
        mlp_activation="gelu",
        gated_mlp=False,
        norm_kind="layernorm",
        rope_theta=0.0,               # learned positions
        frontend="vision",
    )


DEIT_T = _vit("deit-t", heads=3, dim=192, depth=12)
DEIT_160 = _vit("deit-160", heads=4, dim=160, depth=12)
DEIT_256 = _vit("deit-256", heads=4, dim=256, depth=12)
LV_VIT_T = _vit("lv-vit-t", heads=4, dim=240, depth=12)

VIT_SEQ = 197  # 196 patches + cls


def vit_shape(batch: int) -> ShapeConfig:
    return ShapeConfig(f"vit_b{batch}", VIT_SEQ, batch, "prefill")
