"""xLSTM-125M  [arXiv:2405.04517].

12L d_model=768 4H (kv=4) d_ff=0 (no external FFN — blocks carry their own
up-projections) vocab=50304.  sLSTM + mLSTM blocks; we use the paper's
xLSTM[7:1]-style mix approximated at period 4 (3 mLSTM : 1 sLSTM) so the
smoke test exercises both block kinds.
"""
from repro.configs.base import BlockSpec, ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=(
        BlockSpec("mlstm", "none"),
        BlockSpec("mlstm", "none"),
        BlockSpec("mlstm", "none"),
        BlockSpec("slstm", "none"),
    ),
    xlstm=XLSTMConfig(mlstm_proj_factor=2.0, slstm_proj_factor=4.0 / 3.0),
    norm_kind="layernorm",
    tie_embeddings=True,
    subquadratic=True,
)
