"""Qwen2-VL-72B  [arXiv:2409.12191].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.  M-RoPE with
(temporal, height, width) sections; dynamic-resolution vision tower is a
STUB — ``input_specs()`` provides precomputed patch embeddings merged into
the token stream.
"""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29_568,
    vocab_size=152_064,
    block_pattern=(BlockSpec("attn", "dense"),),
    mrope_sections=(16, 24, 24),   # sums to head_dim/2 = 64
    rope_theta=1_000_000.0,
    mlp_activation="silu",
    norm_kind="rmsnorm",
    frontend="vision",
)
