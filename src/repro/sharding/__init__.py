from repro.sharding.rules import (batch_axes_for, input_shardings_tree,
                                  input_specs_tree, param_shardings,
                                  param_specs)
