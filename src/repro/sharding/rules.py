"""Logical-axis sharding rules: param/input pytrees -> PartitionSpecs.

Megatron-style TP over the ``model`` axis, DP over ``("pod","data")``:
  * attention: wq/wk/wv column-parallel, wo row-parallel;
  * MLP: wi/wg column-parallel, wo row-parallel;
  * MoE: TP-within-expert by default (dispatch stays local to the data
    shard); ``expert_parallel=True`` switches to EP (experts over model);
  * embeddings vocab-sharded when divisible (else replicated — e.g.
    granite's vocab 49155 is indivisible by 16);
  * KV caches: sequence(W)-sharded over ``model`` (FlashDecoding-style
    KV-split — the memory owner of long contexts), batch over data axes.

Stacked stack-params carry a leading group axis (never sharded; it is the
scan dimension — or the ``stage`` axis for the SSR pipeline executor).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.backend.compat import mesh_axis_size  # noqa: F401  (public API)

BATCH_AXES = ("pod", "data")


def batch_axes_for(mesh, batch: int) -> Optional[Tuple[str, ...]]:
    """Largest prefix of ('pod','data') whose product divides batch."""
    axes = [a for a in BATCH_AXES if a in mesh.axis_names]
    out = []
    prod = 1
    for a in axes:
        sz = mesh.shape[a]
        if batch % (prod * sz) == 0:
            out.append(a)
            prod *= sz
    return tuple(out) if out else None


def _maybe(mesh, axis: str, dim: int) -> Optional[str]:
    """Shard `dim` over `axis` only if present and divisible."""
    if axis in mesh.axis_names and dim % mesh.shape[axis] == 0:
        return axis
    return None


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def _param_spec(path: Tuple[str, ...], leaf, mesh, *,
                expert_parallel: bool = False) -> P:
    """Spec from the param path.  Leaves under 'stack'/'enc_stack' have a
    leading group axis."""
    names = [getattr(k, "key", str(k)) for k in path]
    leading = 1 if any(n in ("stack", "enc_stack") for n in names) else 0
    nd = leaf.ndim
    last = names[-1]
    mdl = "model" if "model" in mesh.axis_names else None

    def spec(*tail):
        full = [None] * leading + list(tail)
        full += [None] * (nd - len(full))
        return P(*full[:nd])

    if mdl is None:
        return P(*([None] * nd))

    in_moe = "ffn" in names and nd - leading == 3  # (E, d, f) expert weights
    msz = mesh.shape["model"]

    if last in ("wq", "wk", "wv", "wi", "wg", "up", "up_proj", "in_proj",
                "dt_proj", "w_x"):
        if in_moe:
            if expert_parallel:
                return spec(_maybe(mesh, "model", leaf.shape[leading]), None,
                            None)
            return spec(None, None, _maybe(mesh, "model", leaf.shape[-1]))
        return spec(None, _maybe(mesh, "model", leaf.shape[-1]))
    if last in ("wo", "down", "down_proj", "out_proj", "x_proj"):
        if in_moe:
            if expert_parallel:
                return spec(_maybe(mesh, "model", leaf.shape[leading]), None,
                            None)
            return spec(None, _maybe(mesh, "model", leaf.shape[-2]), None)
        return spec(_maybe(mesh, "model", leaf.shape[leading]), None)
    if last == "w_h":                           # slstm (H, hd, 4hd)
        return spec(_maybe(mesh, "model", leaf.shape[leading]), None, None)
    if last in ("conv_w",):                     # (k, d_inner)
        return spec(None, _maybe(mesh, "model", leaf.shape[-1]))
    if last in ("conv_b", "dt_bias", "D"):      # (d_inner,)
        return spec(_maybe(mesh, "model", leaf.shape[-1]))
    if last == "A_log":                         # (d_inner, n)
        return spec(_maybe(mesh, "model", leaf.shape[leading]), None)
    if last == "table":                         # (V, D) vocab-sharded
        return P(_maybe(mesh, "model", leaf.shape[0]), None)
    if last == "w" and "head" in names:         # (D, V)
        return P(None, _maybe(mesh, "model", leaf.shape[-1]))
    if last == "router":
        return spec(None, None)
    # norms, biases, gates, pos embeddings: replicated
    return P(*([None] * nd))


def _fsdp_extend(spec: P, leaf, mesh, axes=("data",)) -> P:
    """FSDP: additionally shard the weight over the data axis on the first
    unsharded, divisible, non-scan dim (dim 0 of stacked params is the scan
    axis: sharding it would gather whole layers, so prefer dims >= 1 and
    only fall back to dim 0 for non-stacked leaves)."""
    for ax in axes:
        if ax not in mesh.axis_names:
            return spec
    sz = int(np.prod([mesh.shape[a] for a in axes]))
    entries = list(spec) + [None] * (leaf.ndim - len(spec))
    order = list(range(1, leaf.ndim)) + ([0] if leaf.ndim == 2 else [])
    for i in order:
        if entries[i] is None and leaf.shape[i] % sz == 0 \
                and leaf.shape[i] >= sz:
            entries[i] = axes[0] if len(axes) == 1 else tuple(axes)
            return P(*entries)
    return spec


def param_shardings(params, mesh: Mesh, *, expert_parallel: bool = False,
                    fsdp: bool = False):
    """Pytree of NamedShardings matching `params`."""
    specs = param_specs(params, mesh, expert_parallel=expert_parallel,
                        fsdp=fsdp)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def param_specs(params, mesh: Mesh, *, expert_parallel: bool = False,
                fsdp: bool = False):
    def f(path, leaf):
        spec = _param_spec(path, leaf, mesh, expert_parallel=expert_parallel)
        if fsdp and leaf.ndim >= 2:
            spec = _fsdp_extend(spec, leaf, mesh)
        return spec
    return jax.tree_util.tree_map_with_path(f, params)


# ---------------------------------------------------------------------------
# input rules
# ---------------------------------------------------------------------------

def input_specs_tree(batch_tree, mesh: Mesh, *, seq_axis_for_cache=True):
    """Shardings for a model-input pytree (tokens/labels/embeds/positions/
    cache/cache_index) based on leaf path + rank."""
    def f(path, leaf):
        names = [getattr(k, "key", str(k)) for k in path]
        nd = leaf.ndim
        in_cache = "cache" in names
        if in_cache:
            return _cache_spec(names, leaf, mesh)
        if names and names[0] == "positions":
            bspec = batch_axes_for(mesh, leaf.shape[1])
            return P(None, bspec, None)
        if nd == 0:
            return P()
        bspec = batch_axes_for(mesh, leaf.shape[0])
        return P(*([bspec] + [None] * (nd - 1)))
    return jax.tree_util.tree_map_with_path(f, batch_tree)


def _cache_spec(names, leaf, mesh) -> P:
    """Cache leaves are stacked (G, B, ...).  KV caches (G,B,W,Hk,hd):
    shard W over model (+ data when batch doesn't use it).  Recurrent
    states (G,B,...): shard trailing feature dim over model if divisible."""
    nd = leaf.ndim
    B = leaf.shape[1]
    bspec = batch_axes_for(mesh, B)
    if "kv" in names or "cross_kv" in names:       # (G, B, W, Hk, hd)
        W = leaf.shape[2]
        seq_axes = []
        if bspec is None:
            for a in ("data",):
                if a in mesh.axis_names and W % mesh.shape[a] == 0:
                    seq_axes.append(a)
        if "model" in mesh.axis_names and W % mesh.shape["model"] == 0:
            seq_axes.append("model")
        sspec = tuple(seq_axes) if seq_axes else None
        return P(None, bspec, sspec, None, None)
    # recurrent state: shard the largest trailing dim over model
    if nd >= 3:
        dims = list(leaf.shape[2:])
        tgt = int(np.argmax(dims)) + 2
        ax = _maybe(mesh, "model", leaf.shape[tgt])
        spec = [None, bspec] + [None] * (nd - 2)
        spec[tgt] = ax
        return P(*spec)
    return P(None, bspec)


def input_shardings_tree(batch_tree, mesh: Mesh):
    specs = input_specs_tree(batch_tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
