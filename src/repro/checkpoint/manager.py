"""Fault-tolerant checkpointing.

Atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` to
``<dir>/step_<n>`` — a crash mid-save never corrupts the latest checkpoint.
Manifest carries step, pytree structure, and a content checksum; restore
validates it.  ``restore(..., shardings=...)`` re-shards onto a *different*
mesh (elastic scaling after node loss).  ``CheckpointManager`` adds async
saves (background thread) and keep-N retention.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(tree, directory: str, step: int) -> str:
    """Atomic synchronous save.  Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    h = hashlib.sha256()
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: v for k, v in flat.items()})
    for k in sorted(flat):
        h.update(k.encode())
        h.update(flat[k].tobytes())
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "checksum": h.hexdigest(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(tree_like, directory: str, step: Optional[int] = None,
            shardings=None, validate: bool = True):
    """Restore into the structure of `tree_like`.  `shardings` (same
    structure) re-shards each leaf onto the current mesh — pass shardings
    built from a *new* mesh to elastically rescale."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    if validate:
        h = hashlib.sha256()
        for k in sorted(manifest["keys"]):
            h.update(k.encode())
            h.update(data[k].tobytes())
        if h.hexdigest() != manifest["checksum"]:
            raise IOError(f"checkpoint {path} checksum mismatch")

    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    flat_shardings = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(leaves_paths))
    out = []
    for (pth, leaf), shd in zip(leaves_paths, flat_shardings):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in pth)
        arr = data[key]
        if shd is not None:
            arr = jax.device_put(arr, shd)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), step


class CheckpointManager:
    """Async + retention on top of save/restore."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def save(self, tree, step: int):
        # snapshot to host first so donation/mutation can't race the writer
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        if self._thread is not None:
            self._thread.join()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(host, step), daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(host, step)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, tree_like, shardings=None):
        return restore(tree_like, self.directory, shardings=shardings)

    def _save_and_gc(self, tree, step: int):
        save(tree, self.directory, step)
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
