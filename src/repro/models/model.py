"""Model facade: init / forward / loss / prefill / decode for every family.

``build_model(cfg)`` returns a ``Model`` whose methods are pure functions of
(params, batch) — suitable for jit/pjit, the SSR pipeline executor, and the
dry-run's ``.lower().compile()``.

Input contracts per family (see ``input_specs``):
  * LM (dense/moe/hybrid/ssm):  tokens (B,S) i32, labels (B,S) i32
  * vlm:   embeds (B,S,D) — merged text+vision embeddings (vision tower is a
           stub per the assignment), positions (3,B,S) for M-RoPE
  * audio: enc_embeds (B,S,D) frame embeddings (conv frontend stub),
           dec_tokens (B,S/4), labels (B,S/4)
  * vision (DeiT): embeds (B,197,D) patch embeddings, labels (B,) classes
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import transformer as T

AUDIO_DECODER_RATIO = 4  # decoder length = seq_len // 4 for audio shapes


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------ init
    def init(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        ks = L.split_keys(rng, 6)
        params: Dict[str, Any] = {}
        if cfg.family == "audio":
            params["enc_stack"] = T.init_stack(ks[0], cfg)
            params["enc_norm"] = L.init_norm(cfg)
            params["embed"] = L.init_embedding(ks[1], cfg)
            params["stack"] = T.init_stack(ks[2], cfg, cross=True)
            params["final_norm"] = L.init_norm(cfg)
        elif cfg.family == "vision":
            params["pos_embed"] = 0.02 * jax.random.normal(
                ks[0], (1, 256, cfg.d_model), jnp.float32)
            params["cls"] = jnp.zeros((1, 1, cfg.d_model), jnp.float32)
            params["stack"] = T.init_stack(ks[2], cfg)
            params["final_norm"] = L.init_norm(cfg)
            params["head"] = {"w": L.dense_init(
                ks[3], (cfg.d_model, cfg.vocab_size), cfg.d_model,
                jnp.dtype(cfg.param_dtype))}
        else:  # LM families (dense / moe / hybrid / ssm / vlm)
            params["embed"] = L.init_embedding(ks[1], cfg)
            params["stack"] = T.init_stack(ks[2], cfg)
            params["final_norm"] = L.init_norm(cfg)
            if not cfg.tie_embeddings:
                params["head"] = {"w": L.dense_init(
                    ks[3], (cfg.d_model, cfg.vocab_size), cfg.d_model,
                    jnp.dtype(cfg.param_dtype))}
        return params

    # --------------------------------------------------------------- forward
    def _lm_hidden(self, params, x, *, positions=None, cache=None,
                   cache_index=None, remat=False, collect_state=False,
                   block_tables=None, write_tables=None):
        cfg = self.cfg
        x = T.shard_act(x)
        x, new_cache, aux = T.run_stack(
            params["stack"], x, cfg, positions=positions, causal=True,
            cache=cache, cache_index=cache_index, remat=remat,
            collect_state=collect_state, block_tables=block_tables,
            write_tables=write_tables)
        x = L.apply_norm(params["final_norm"], x, cfg)
        return x, new_cache, aux

    def _lm_trunk(self, params, x, *, positions=None, cache=None,
                  cache_index=None, remat=False, collect_state=False,
                  block_tables=None):
        x, new_cache, aux = self._lm_hidden(
            params, x, positions=positions, cache=cache,
            cache_index=cache_index, remat=remat,
            collect_state=collect_state, block_tables=block_tables)
        logits = L.logits_head(params.get("embed"), params.get("head"), x,
                               self.cfg)
        return logits, new_cache, aux

    def _head_weight(self, params):
        cfg = self.cfg
        if cfg.tie_embeddings or params.get("head") is None:
            return params["embed"]["table"].T
        return params["head"]["w"]

    def _use_chunked_ce(self) -> bool:
        import os
        thresh = int(os.environ.get("REPRO_CHUNKED_CE", 65536))
        return bool(thresh) and self.cfg.vocab_size >= thresh \
            and self.cfg.family not in ("vision",)

    def forward(self, params, batch, *, remat: bool = False):
        """Full forward pass -> (logits, aux_loss)."""
        cfg = self.cfg
        if cfg.family == "vision":
            x = batch["embeds"].astype(cfg.dtype)
            b, s, _ = x.shape
            cls = jnp.broadcast_to(params["cls"].astype(cfg.dtype),
                                   (b, 1, cfg.d_model))
            x = jnp.concatenate([cls, x], axis=1)
            x = x + params["pos_embed"][:, :s + 1].astype(cfg.dtype)
            x, _, aux = T.run_stack(params["stack"], x, cfg, causal=False,
                                    remat=remat)
            x = L.apply_norm(params["final_norm"], x, cfg)
            logits = jnp.einsum("bd,dv->bv", x[:, 0], params["head"]["w"],
                                preferred_element_type=jnp.float32)
            return logits, aux
        if cfg.family == "audio":
            enc = self.encode(params, batch["enc_embeds"], remat=remat)
            y = L.embed(params["embed"], batch["dec_tokens"], cfg)
            y = y.astype(cfg.dtype)
            pos = L.sinusoidal_positions(y.shape[1], cfg.d_model)
            y = y + pos[None].astype(cfg.dtype)
            y, _, aux = T.run_stack(params["stack"], y, cfg, causal=True,
                                    enc_out=enc, remat=remat)
            y = L.apply_norm(params["final_norm"], y, cfg)
            logits = L.logits_head(params["embed"], params.get("head"), y, cfg)
            return logits, aux
        # LM
        if "embeds" in batch:
            x = batch["embeds"].astype(cfg.dtype)
        else:
            x = L.embed(params["embed"], batch["tokens"], cfg).astype(cfg.dtype)
        logits, _, aux = self._lm_trunk(
            params, x, positions=batch.get("positions"), remat=remat)
        return logits, aux

    def encode(self, params, enc_embeds, *, remat=False):
        cfg = self.cfg
        x = enc_embeds.astype(cfg.dtype)
        pos = L.sinusoidal_positions(x.shape[1], cfg.d_model)
        x = x + pos[None].astype(cfg.dtype)
        x, _, _ = T.run_stack(params["enc_stack"], x, cfg, causal=False,
                              remat=remat)
        return L.apply_norm(params["enc_norm"], x, cfg)

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch, *, remat: bool = False):
        cfg = self.cfg
        labels = batch["labels"]
        if cfg.family == "vision":
            logits, aux = self.forward(params, batch, remat=remat)
            lp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(lp, labels[:, None], axis=-1)
            return jnp.mean(nll) + 0.01 * aux

        if self._use_chunked_ce():
            # fused head+CE: the (tokens, vocab) logits never materialize
            # (dominant train activation for 256k-vocab archs — §Perf).
            hidden, aux = self._hidden_for_loss(params, batch, remat=remat)
            n = hidden.shape[0] * hidden.shape[1]
            nll = L.chunked_softmax_xent(
                hidden.reshape(n, cfg.d_model), self._head_weight(params),
                labels.reshape(n), cfg)
            mask = (labels.reshape(n) >= 0).astype(jnp.float32)
            loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
            return loss + 0.01 * aux

        logits, aux = self.forward(params, batch, remat=remat)
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, safe[..., None], axis=-1)[..., 0]
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss + 0.01 * aux

    def _hidden_for_loss(self, params, batch, *, remat=False):
        """Final hidden states (pre-head) for the fused-CE path."""
        cfg = self.cfg
        if cfg.family == "audio":
            enc = self.encode(params, batch["enc_embeds"], remat=remat)
            y = L.embed(params["embed"], batch["dec_tokens"], cfg)
            y = y.astype(cfg.dtype)
            pos = L.sinusoidal_positions(y.shape[1], cfg.d_model)
            y = y + pos[None].astype(cfg.dtype)
            y, _, aux = T.run_stack(params["stack"], y, cfg, causal=True,
                                    enc_out=enc, remat=remat)
            return L.apply_norm(params["final_norm"], y, cfg), aux
        if "embeds" in batch:
            x = batch["embeds"].astype(cfg.dtype)
        else:
            x = L.embed(params["embed"], batch["tokens"], cfg).astype(cfg.dtype)
        hidden, _, aux = self._lm_hidden(
            params, x, positions=batch.get("positions"), remat=remat)
        return hidden, aux

    # --------------------------------------------------------------- serving
    def init_cache(self, batch: int, max_seq: int, enc_len: int = 0,
                   factory=None):
        return T.make_cache(self.cfg, batch, max_seq, enc_len=enc_len,
                            factory=factory)

    def init_paged_cache(self, batch: int, max_seq: int, *, page_size: int,
                         num_blocks: int, factory=None,
                         kv_dtype: str = "fp"):
        """Pool-backed slot cache: global-attention KV as physical pages
        (int8 rows + per-row scales under ``kv_dtype="int8"``), everything
        else dense (see ``transformer.make_paged_cache``)."""
        return T.make_paged_cache(self.cfg, batch, max_seq,
                                  page_size=page_size,
                                  num_blocks=num_blocks, factory=factory,
                                  kv_dtype=kv_dtype)

    def prefill(self, params, batch, max_seq: int):
        """Process the prompt; returns (logits_last, cache)."""
        cfg = self.cfg
        if cfg.family == "audio":
            enc = self.encode(params, batch["enc_embeds"])
            y = L.embed(params["embed"], batch["dec_tokens"], cfg)
            y = y.astype(cfg.dtype)
            pos = L.sinusoidal_positions(y.shape[1], cfg.d_model)
            y = y + pos[None].astype(cfg.dtype)
            cache = self.init_cache(y.shape[0], max_seq,
                                    enc_len=enc.shape[1])
            y, cache, _ = T.run_stack(
                params["stack"], y, cfg, causal=True, enc_out=enc,
                cache=cache, cache_index=jnp.int32(0), collect_state=True)
            y = L.apply_norm(params["final_norm"], y, cfg)
            logits = L.logits_head(params["embed"], params.get("head"),
                                   y[:, -1:], cfg)
            return logits, cache
        if "embeds" in batch:
            x = batch["embeds"].astype(cfg.dtype)
        else:
            x = L.embed(params["embed"], batch["tokens"], cfg).astype(cfg.dtype)
        cache = self.init_cache(x.shape[0], max_seq)
        logits, cache, _ = self._lm_trunk(
            params, x, positions=batch.get("positions"), cache=cache,
            cache_index=jnp.int32(0), collect_state=True)
        return logits[:, -1:], cache

    def prefill_one(self, params, tokens, length, max_seq: int):
        """Batch-1 prompt prefill against a FRESH dense cache (LM families
        only) — the admission primitive both cache layouts share.

        ``tokens`` (1, P) is right-padded; ``length`` (traced scalar) is
        the true prompt length.  Returns (logits at the last valid prompt
        position (1, 1, V), the batch-1 cache) — the caller scatters the
        cache into its persistent slot store (``scatter_cache_slot``).
        Pool-backed engines do NOT stage through here: they run
        ``prefill_suffix_paged``, which writes pages directly."""
        cfg = self.cfg
        if cfg.family in ("audio", "vision", "vlm") or cfg.mrope_sections:
            raise NotImplementedError(
                "per-slot prefill serves token-LM families "
                "(dense/moe/hybrid/ssm)")
        x = L.embed(params["embed"], tokens, cfg).astype(cfg.dtype)
        cache = self.init_cache(x.shape[0], max_seq)
        hidden, cache, _ = self._lm_hidden(
            params, x, cache=cache, cache_index=jnp.int32(0),
            collect_state=True)
        last = lax.dynamic_slice_in_dim(hidden, length - 1, 1, axis=1)
        logits = L.logits_head(params.get("embed"), params.get("head"),
                               last, cfg)
        return logits, cache

    def prefill_into_slot(self, params, full_cache, tokens, slot, length,
                          max_seq: int):
        """Per-slot prefill for continuous batching (LM families only).

        Runs ``prefill_one`` then scatters its cache into batch row
        ``slot`` of the persistent ``full_cache`` without touching any
        other slot.  Padding is exact under causal attention (pad tokens
        sit *after* every valid token, and their cache rows are
        overwritten by decode before any length mask admits them).

        Returns (logits at the last valid prompt position (1, 1, V),
        new_full_cache).  Admission cost is O(prompt), independent of how
        many other slots are mid-decode."""
        logits, cache = self.prefill_one(params, tokens, length, max_seq)
        return logits, T.scatter_cache_slot(full_cache, cache, slot)

    def prefill_suffix_paged(self, params, full_cache, tokens, slot,
                             offset, length, max_seq: int, block_tables,
                             write_tables):
        """Paged end-to-end prefill into slot ``slot`` of a pool-backed
        cache (LM families only): the prompt SUFFIX streams straight into
        the pool — no dense staging buffer, no commit-time copy.

        ``tokens`` (1, S) is the right-padded unmatched suffix;
        ``offset`` (traced scalar) counts the prefix tokens already
        sitting in shared pages (0 on a cold admission — then the suffix
        is the whole prompt); ``length`` (traced scalar) is the true
        suffix length.  ``block_tables`` (1, NB) maps every logical block
        of the request — shared prefix and fresh suffix — for the
        attention gather; ``write_tables`` (1, NB) names only the fresh
        blocks (sentinel elsewhere) so shared pages are never written.

        Global-attention K/V lands in its physical pages as the stack
        runs; dense per-slot state (SSM, local-window rings) rides a
        batch-1 part view and scatters into batch row ``slot`` at the
        end.  Returns (logits at the last valid suffix position (1,1,V),
        new_full_cache)."""
        cfg = self.cfg
        if cfg.family in ("audio", "vision", "vlm") or cfg.mrope_sections:
            raise NotImplementedError(
                "per-slot prefill serves token-LM families "
                "(dense/moe/hybrid/ssm)")
        x = L.embed(params["embed"], tokens, cfg).astype(cfg.dtype)
        view = T.combine_prefill_parts(
            full_cache, T.make_prefill_part(cfg, max_seq))
        hidden, new_view, _ = self._lm_hidden(
            params, x, cache=view, cache_index=jnp.asarray(offset, jnp.int32),
            collect_state=True, block_tables=block_tables,
            write_tables=write_tables)
        last = lax.dynamic_slice_in_dim(hidden, length - 1, 1, axis=1)
        logits = L.logits_head(params.get("embed"), params.get("head"),
                               last, cfg)
        return logits, T.merge_prefill_view(full_cache, new_view, slot)

    def decode_step(self, params, cache, tokens, cache_index,
                    positions=None, block_tables=None):
        """One decode step.  tokens: (B, S) — S = 1 for plain decode, or
        S = K+1 for a speculative-verify window (current token + K
        drafted tokens per slot, scored in one step).  Returns
        (logits, new_cache).

        ``cache_index`` is a scalar when all rows decode in lock-step, or a
        (B,) vector of per-slot positions for continuous batching (each
        slot then writes its own cache row(s) and attends under its own
        length mask — see ``layers.multi_head_attention``).
        ``block_tables`` maps logical to physical pages when ``cache`` is
        pool-backed (``transformer.make_paged_cache``)."""
        cfg = self.cfg
        x = L.embed(params["embed"], tokens, cfg).astype(cfg.dtype)
        if cfg.family == "audio":
            pos = L.sinusoidal_position_at(cache_index, cfg.d_model)
            x = x + pos[None, None].astype(cfg.dtype)
            x, cache, _ = T.run_stack(
                params["stack"], x, cfg, causal=True, cache=cache,
                cache_index=cache_index, collect_state=True)
            x = L.apply_norm(params["final_norm"], x, cfg)
            logits = L.logits_head(params["embed"], params.get("head"), x, cfg)
            return logits, cache
        logits, cache, _ = self._lm_trunk(
            params, x, positions=positions, cache=cache,
            cache_index=cache_index, collect_state=True,
            block_tables=block_tables)
        return logits, cache

    # ------------------------------------------------------------ input spec
    def input_specs(self, shape: ShapeConfig, *, batch_override: int = 0
                    ) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this shape
        (weak-type-correct, shardable, no device allocation)."""
        cfg = self.cfg
        B = batch_override or shape.global_batch
        S = shape.seq_len
        i32, dt = jnp.int32, jnp.dtype(cfg.dtype)

        if cfg.family == "vision":
            return {"embeds": _sds((B, S, cfg.d_model), dt),
                    "labels": _sds((B,), i32)}

        if cfg.family == "audio":
            dec = max(S // AUDIO_DECODER_RATIO, 8)
            if shape.is_decode:
                cache = T.make_cache(cfg, B, S, enc_len=S, factory=_sds)
                return {"tokens": _sds((B, 1), i32),
                        "cache": cache,
                        "cache_index": _sds((), i32)}
            return {"enc_embeds": _sds((B, S, cfg.d_model), dt),
                    "dec_tokens": _sds((B, dec), i32),
                    "labels": _sds((B, dec), i32)}

        if shape.is_decode:
            spec = {"tokens": _sds((B, 1), i32),
                    "cache": T.make_cache(cfg, B, S, factory=_sds),
                    "cache_index": _sds((), i32)}
            if cfg.mrope_sections:
                spec["positions"] = _sds((3, B, 1), i32)
            return spec

        spec = {}
        if cfg.family == "vlm":
            spec["embeds"] = _sds((B, S, cfg.d_model), dt)
        else:
            spec["tokens"] = _sds((B, S), i32)
        spec["labels"] = _sds((B, S), i32)
        if cfg.mrope_sections:
            spec["positions"] = _sds((3, B, S), i32)
        return spec

    # --------------------------------------------------------------- counts
    def param_count(self, params) -> int:
        return sum(int(x.size) for x in jax.tree.leaves(params))


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
