"""State-space / recurrent mixers: Mamba-1 (jamba), mLSTM + sLSTM (xlstm).

Each mixer exposes:
  init_<kind>(key, cfg)                          -> params
  apply_<kind>(p, x, cfg, state=None)            -> (y, new_state)
where ``state`` is the O(1) recurrent state used for decode; ``state=None``
runs the full-sequence (chunked-parallel where possible) form.

The inner recurrences route through the kernel dispatch front door
(``repro.backend.dispatch.dispatch_linear_scan`` — Pallas on TPU, chunked
``jax.lax`` elsewhere) — this is the TPU analogue of the
paper's line-buffer fine-grained pipeline: a single streaming pass that
carries running state instead of a second full read of the sequence.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, split_keys

# ---------------------------------------------------------------------------
# generic gated linear recurrence   h_t = a_t * h_{t-1} + b_t
# ---------------------------------------------------------------------------

def mamba_scan_fused(delta, xi, Bm, Cm, A, h0=None, chunk: int = 128):
    """Memory-lean selective scan: computes a_t = exp(Δ·A) and b_t = Δ·B·x
    INSIDE the chunk loop so the (B,S,d_inner,d_state) gate/input tensors
    never materialize in HBM — only (B,chunk,d_inner,d_state) working sets.
    Returns (y = C·h per step (B,S,d_inner), h_last).

    delta, xi: (B,S,di) fp32; Bm, Cm: (B,S,n) fp32; A: (di,n)."""
    Bsz, S, di = delta.shape
    n = A.shape[1]
    if S % chunk:
        chunk = S if S < chunk else math.gcd(S, chunk) or 1
    n_chunks = S // chunk
    if h0 is None:
        h0 = jnp.zeros((Bsz, di, n), jnp.float32)

    def to_chunks(x):
        return jnp.moveaxis(x, 1, 0).reshape((n_chunks, chunk) + x.shape[:1]
                                             + x.shape[2:])

    dc, xc, bc, cc = map(to_chunks, (delta, xi, Bm, Cm))

    def body(h, inp):
        d_c, x_c, b_c, c_c = inp                  # (chunk, B, ...)
        a = jnp.exp(d_c[..., None] * A)           # (chunk,B,di,n)
        b = (d_c * x_c)[..., None] * b_c[:, :, None, :]

        def assoc(l, r):
            al, bl = l
            ar, br = r
            return al * ar, br + ar * bl
        a_acc, b_acc = lax.associative_scan(assoc, (a, b), axis=0)
        h_all = a_acc * h[None] + b_acc
        y = jnp.einsum("tbin,tbn->tbi", h_all, c_c)
        return h_all[-1], y

    h_last, y_chunks = lax.scan(body, h0, (dc, xc, bc, cc))
    y = jnp.moveaxis(y_chunks.reshape((S, Bsz, di)), 0, 1)
    return y, h_last


def linear_scan_chunked(a, b, h0=None, chunk: int = 128):
    """Chunked scan along axis 1 (seq).  a, b: (B, S, ...) broadcastable.
    Returns all h: (B, S, ...) and final state.  Memory stays O(B*chunk*state)
    inside each chunk (the within-chunk scan is associative/parallel)."""
    B, S = b.shape[0], b.shape[1]
    if h0 is None:
        h0 = jnp.zeros_like(b[:, 0])
    if S % chunk:
        chunk = S if S < chunk else math.gcd(S, chunk) or 1
    n_chunks = S // chunk

    def body(h, ab):
        a_c, b_c = ab                                   # (chunk, B, ...)
        def assoc(l, r):
            al, bl = l
            ar, br = r
            return al * ar, br + ar * bl
        a_acc, b_acc = lax.associative_scan(assoc, (a_c, b_c), axis=0)
        h_all = a_acc * h[None] + b_acc                 # (chunk, B, ...)
        return h_all[-1], h_all

    a_t = jnp.moveaxis(a, 1, 0).reshape((n_chunks, chunk) + a.shape[:1] + a.shape[2:])
    b_t = jnp.moveaxis(b, 1, 0).reshape((n_chunks, chunk) + b.shape[:1] + b.shape[2:])
    h_last, h_chunks = lax.scan(body, h0, (a_t, b_t))
    h_all = h_chunks.reshape((S,) + b.shape[:1] + b.shape[2:])
    return jnp.moveaxis(h_all, 0, 1), h_last


def _scan_dispatch(a, b, h0=None):
    """Route the (B,S,di,n) recurrence through the Pallas linear-scan kernel
    on TPU, chunked associative scan elsewhere.  Returns (h_all, h_last).
    The path choice lives in the dispatch front door."""
    from repro.backend import dispatch
    if dispatch.use_scan_kernel():
        B, S = a.shape[:2]
        feat = a.shape[2:]
        f = 1
        for d in feat:
            f *= d
        h0f = None if h0 is None else h0.reshape(B, f)
        h_all = dispatch.dispatch_linear_scan(
            a.reshape(B, S, f), b.reshape(B, S, f), h0f)
        h_all = h_all.reshape((B, S) + feat)
        return h_all, h_all[:, -1].astype(jnp.float32)
    return linear_scan_chunked(a, b, h0)


# ---------------------------------------------------------------------------
# Mamba-1 mixer
# ---------------------------------------------------------------------------

def mamba_dims(cfg: ModelConfig):
    d_inner = cfg.ssm.expand * cfg.d_model
    dt_rank = cfg.ssm.dt_rank or math.ceil(cfg.d_model / 16)
    return d_inner, dt_rank


def init_mamba(key, cfg: ModelConfig):
    d, s = cfg.d_model, cfg.ssm.d_state
    d_inner, dt_rank = mamba_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 6)
    a_init = jnp.tile(jnp.arange(1, s + 1, dtype=jnp.float32)[None, :],
                      (d_inner, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_inner), d, dt),
        "conv_w": dense_init(ks[1], (cfg.ssm.d_conv, d_inner), cfg.ssm.d_conv,
                             jnp.float32),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "x_proj": dense_init(ks[2], (d_inner, dt_rank + 2 * s), d_inner, dt),
        "dt_proj": dense_init(ks[3], (dt_rank, d_inner), dt_rank, jnp.float32),
        "dt_bias": jnp.full((d_inner,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(a_init),                           # (d_inner, s)
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_inner, d), d_inner, dt),
    }


def _mamba_conv(p, x_in, conv_state):
    """Depthwise causal conv over seq.  x_in: (B, S, d_inner).
    conv_state: (B, d_conv-1, d_inner) history or None."""
    k = p["conv_w"].shape[0]
    B = x_in.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((B, k - 1, x_in.shape[-1]), x_in.dtype)
    padded = jnp.concatenate([conv_state, x_in], axis=1)
    out = jnp.zeros_like(x_in, dtype=jnp.float32)
    for i in range(k):
        out = out + padded[:, i:i + x_in.shape[1]].astype(jnp.float32) \
            * p["conv_w"][i]
    out = out + p["conv_b"]
    new_state = padded[:, -(k - 1):]
    return jax.nn.silu(out).astype(x_in.dtype), new_state


def apply_mamba(p, x, cfg: ModelConfig, state: Optional[dict] = None):
    """x: (B, S, d).  state: {"conv": (B,k-1,di), "ssm": (B,di,s)} or None."""
    B, S, _ = x.shape
    d_inner, dt_rank = mamba_dims(cfg)
    n = cfg.ssm.d_state
    dt_ = x.dtype

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"],
                    preferred_element_type=jnp.float32).astype(dt_)
    xi, z = jnp.split(xz, 2, axis=-1)

    conv_state = state["conv"] if state is not None else None
    xi, new_conv = _mamba_conv(p, xi, conv_state)

    proj = jnp.einsum("bsi,ie->bse", xi, p["x_proj"],
                      preferred_element_type=jnp.float32)
    dt_raw, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_raw, p["dt_proj"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                  # (di, n)

    # selective SSM:  h_t = exp(delta*A) h_{t-1} + delta*B_t*x_t ; y = C_t.h
    import os
    h0 = state["ssm"] if state is not None else None
    if os.environ.get("REPRO_MAMBA", "fused") == "fused" and S > 1:
        # fused chunk path: gate/input tensors never materialize at full
        # sequence length (hillclimb §Perf: -2x HBM on mamba layers)
        y, h_last = mamba_scan_fused(delta, xi.astype(jnp.float32),
                                     Bm, Cm, A, h0)
    else:
        a = jnp.exp(delta[..., None] * A)                     # (B,S,di,n)
        b = (delta * xi.astype(jnp.float32))[..., None] * Bm[:, :, None, :]
        h_all, h_last = _scan_dispatch(a, b, h0)
        y = jnp.einsum("bsin,bsn->bsi", h_all, Cm)
    y = y + xi.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(dt_)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"],
                     preferred_element_type=jnp.float32).astype(dt_)
    return out, {"conv": new_conv, "ssm": h_last}


def mamba_state_shape(cfg: ModelConfig, batch: int):
    d_inner, _ = mamba_dims(cfg)
    return {
        "conv": (batch, cfg.ssm.d_conv - 1, d_inner),
        "ssm": (batch, d_inner, cfg.ssm.d_state),
    }


# ---------------------------------------------------------------------------
# mLSTM (matrix memory)
# ---------------------------------------------------------------------------

def mlstm_dims(cfg: ModelConfig):
    d_inner = int(cfg.xlstm.mlstm_proj_factor * cfg.d_model)
    hd = d_inner // cfg.num_heads
    return d_inner, hd


def init_mlstm(key, cfg: ModelConfig):
    d = cfg.d_model
    d_inner, hd = mlstm_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 8)
    return {
        "up_proj": dense_init(ks[0], (d, 2 * d_inner), d, dt),
        "wq": dense_init(ks[1], (d_inner, d_inner), d_inner, dt),
        "wk": dense_init(ks[2], (d_inner, d_inner), d_inner, dt),
        "wv": dense_init(ks[3], (d_inner, d_inner), d_inner, dt),
        "w_i": dense_init(ks[4], (d_inner, cfg.num_heads), d_inner, jnp.float32),
        "w_f": dense_init(ks[5], (d_inner, cfg.num_heads), d_inner, jnp.float32),
        "f_bias": jnp.full((cfg.num_heads,), 3.0, jnp.float32),
        "out_norm": {"scale": jnp.ones((d_inner,), jnp.float32)},
        "down_proj": dense_init(ks[6], (d_inner, d), d_inner, dt),
    }


def apply_mlstm(p, x, cfg: ModelConfig, state: Optional[dict] = None):
    """Chunkwise-parallel mLSTM (TFLA-style, stabilized exponential gating).

    state: {"C": (B,H,hd,hd), "n": (B,H,hd), "m": (B,H)} for decode."""
    B, S, _ = x.shape
    H = cfg.num_heads
    d_inner, hd = mlstm_dims(cfg)
    dt_ = x.dtype

    up = jnp.einsum("bsd,de->bse", x, p["up_proj"],
                    preferred_element_type=jnp.float32).astype(dt_)
    xin, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bse,ef->bsf", xin, p["wq"],
                   preferred_element_type=jnp.float32).reshape(B, S, H, hd)
    k = jnp.einsum("bse,ef->bsf", xin, p["wk"],
                   preferred_element_type=jnp.float32).reshape(B, S, H, hd)
    v = jnp.einsum("bse,ef->bsf", xin, p["wv"],
                   preferred_element_type=jnp.float32).reshape(B, S, H, hd)
    q = q / math.sqrt(hd)
    i_gate = jnp.einsum("bse,eh->bsh", xin.astype(jnp.float32), p["w_i"])
    f_gate = jnp.einsum("bse,eh->bsh", xin.astype(jnp.float32), p["w_f"]) \
        + p["f_bias"]
    log_f = jax.nn.log_sigmoid(f_gate)                        # (B,S,H)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def step(carry, inp):
        C, n, m = carry
        q_t, k_t, v_t, i_t, lf_t = inp                        # (B,H,hd)...
        m_new = jnp.maximum(lf_t + m, i_t)                    # (B,H)
        f_eff = jnp.exp(lf_t + m - m_new)
        i_eff = jnp.exp(i_t - m_new)
        C = f_eff[..., None, None] * C \
            + i_eff[..., None, None] * (k_t[..., :, None] * v_t[..., None, :])
        n = f_eff[..., None] * n + i_eff[..., None] * k_t
        num = jnp.einsum("bhd,bhde->bhe", q_t, C)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", q_t, n))
        den = jnp.maximum(den, jnp.exp(-m_new))
        h_t = num / den[..., None]
        return (C, n, m_new), h_t

    qs = jnp.moveaxis(q.astype(jnp.float32), 1, 0)
    ks_ = jnp.moveaxis(k.astype(jnp.float32), 1, 0)
    vs = jnp.moveaxis(v.astype(jnp.float32), 1, 0)
    is_ = jnp.moveaxis(i_gate, 1, 0)
    lfs = jnp.moveaxis(log_f, 1, 0)
    (C, n, m), h = lax.scan(step, (C0, n0, m0), (qs, ks_, vs, is_, lfs))
    h = jnp.moveaxis(h, 0, 1).reshape(B, S, d_inner)          # (B,S,di)

    # group-norm-ish output norm per head then gate + down-project
    hf = h.reshape(B, S, H, hd)
    var = jnp.mean(jnp.square(hf), axis=-1, keepdims=True)
    hf = hf * lax.rsqrt(var + 1e-6)
    h = hf.reshape(B, S, d_inner) * p["out_norm"]["scale"]
    h = (h * jax.nn.silu(z.astype(jnp.float32))).astype(dt_)
    out = jnp.einsum("bse,ed->bsd", h, p["down_proj"],
                     preferred_element_type=jnp.float32).astype(dt_)
    return out, {"C": C, "n": n, "m": m}


def mlstm_state_shape(cfg: ModelConfig, batch: int):
    _, hd = mlstm_dims(cfg)
    H = cfg.num_heads
    return {"C": (batch, H, hd, hd), "n": (batch, H, hd), "m": (batch, H)}


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, recurrent mixing)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig):
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    H = cfg.num_heads
    hd = d // H
    ks = split_keys(key, 6)
    d_ff = int(cfg.xlstm.slstm_proj_factor * d)
    return {
        # input weights for 4 gates (i, f, z, o)
        "w_x": dense_init(ks[0], (d, 4 * d), d, dt),
        # block-diagonal recurrent weights per head: (H, hd, 4*hd)
        "w_h": dense_init(ks[1], (H, hd, 4 * hd), hd, jnp.float32),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "f_bias": jnp.full((d,), 3.0, jnp.float32),
        "up": dense_init(ks[2], (d, 2 * d_ff), d, dt),
        "down": dense_init(ks[3], (d_ff, d), d_ff, dt),
    }


def apply_slstm(p, x, cfg: ModelConfig, state: Optional[dict] = None):
    """Strictly sequential scalar-memory LSTM with exponential gating.
    state: {"c","n","h","m"} each (B, d)."""
    B, S, d = x.shape
    H = cfg.num_heads
    hd = d // H
    dt_ = x.dtype

    gates_x = jnp.einsum("bsd,de->bse", x, p["w_x"],
                         preferred_element_type=jnp.float32) + p["bias"]

    if state is None:
        zeros = jnp.zeros((B, d), jnp.float32)
        c0, n0, h0 = zeros, zeros, zeros
        m0 = jnp.full((B, d), -1e30, jnp.float32)
    else:
        c0, n0, h0, m0 = state["c"], state["n"], state["h"], state["m"]

    w_h = p["w_h"]                                            # (H, hd, 4hd)

    def step(carry, gx):
        c, n, h, m = carry
        hh = h.reshape(B, H, hd)
        rec = jnp.einsum("bhi,hio->bho", hh, w_h).reshape(B, 4 * d)
        g = gx + rec
        i_r, f_r, z_r, o_r = jnp.split(g, 4, axis=-1)
        f_r = f_r + p["f_bias"]
        m_new = jnp.maximum(jax.nn.log_sigmoid(f_r) + m, i_r)
        i_e = jnp.exp(i_r - m_new)
        f_e = jnp.exp(jax.nn.log_sigmoid(f_r) + m - m_new)
        c = f_e * c + i_e * jnp.tanh(z_r)
        n = f_e * n + i_e
        h_new = jax.nn.sigmoid(o_r) * c / jnp.maximum(n, 1e-6)
        return (c, n, h_new, m_new), h_new

    gx_t = jnp.moveaxis(gates_x, 1, 0)
    (c, n, h, m), hs = lax.scan(step, (c0, n0, h0, m0), gx_t)
    hs = jnp.moveaxis(hs, 0, 1)                               # (B,S,d)

    # post-up/down projection (GLU)
    up = jnp.einsum("bsd,de->bse", hs.astype(dt_), p["up"],
                    preferred_element_type=jnp.float32)
    a, b = jnp.split(up, 2, axis=-1)
    y = (jax.nn.gelu(a) * b).astype(dt_)
    out = jnp.einsum("bse,ed->bsd", y, p["down"],
                     preferred_element_type=jnp.float32).astype(dt_)
    return out, {"c": c, "n": n, "h": h, "m": m}


def slstm_state_shape(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return {"c": (batch, d), "n": (batch, d), "h": (batch, d),
            "m": (batch, d)}
