"""Core transformer layer primitives (pure JAX, functional).

Conventions:
  * params are nested dicts of jnp arrays;
  * activations ``x`` are (batch, seq, d_model);
  * all matmuls accumulate in fp32 (``preferred_element_type``);
  * attention softmax in fp32 (this is also what the Pallas flash kernel
    does — see ``repro.kernels``).

The SSR "fine-grained pipeline" for nonlinear ops appears here as the
*dispatch point*: ``attention``/``rmsnorm`` route through the kernel
dispatch front door (``repro.backend.dispatch``) to the fused Pallas
kernels on TPU and to the jnp reference elsewhere.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, MoEConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(max(in_axis_size, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: Optional[int] = None):
    dim = dim or cfg.d_model
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if cfg.norm_kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), jnp.float32)
    return p


def apply_norm(p, x, cfg: ModelConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm_kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm (gemma-style 1+scale for gemma configs is folded into init)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float, mrope_sections=()):
    """x: (B, S, H, D); positions: (B, S) or (3, B, S) for M-RoPE."""
    b, s, h, d = x.shape
    inv = rope_freqs(d, theta)                      # (d/2,)
    if mrope_sections:
        # M-RoPE: frequency bands split into (temporal, h, w) sections, each
        # rotated by its own position stream.  positions: (3, B, S).
        assert positions.ndim == 3, "M-RoPE needs (3, B, S) positions"
        sec = jnp.concatenate([
            jnp.full((n,), i, jnp.int32)
            for i, n in enumerate(mrope_sections)])  # (d/2,)
        pos = positions.astype(jnp.float32)          # (3, B, S)
        # pick per-frequency position stream: (B, S, d/2)
        pos_per_freq = jnp.take(pos, sec, axis=0)    # (d/2, B, S)
        angles = jnp.einsum("fbs,f->bsf", pos_per_freq, inv)
    else:
        if positions.ndim == 3:
            positions = positions[0]
        angles = positions.astype(jnp.float32)[..., None] * inv  # (B,S,d/2)
    cos = jnp.cos(angles)[:, :, None, :]             # (B,S,1,d/2)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, jnp.float32) * (-math.log(10000.0) / dim))
    pe = jnp.zeros((seq, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def sinusoidal_position_at(pos, dim: int):
    """Single (possibly traced) position -> (dim,) sinusoidal embedding."""
    div = jnp.exp(jnp.arange(0, dim, 2, jnp.float32) * (-math.log(10000.0) / dim))
    ang = pos.astype(jnp.float32) * div
    pe = jnp.zeros((dim,), jnp.float32)
    pe = pe.at[0::2].set(jnp.sin(ang))
    pe = pe.at[1::2].set(jnp.cos(ang))
    return pe


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False):
    kq, kk, kv, ko = split_keys(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": dense_init(kq, (d, qd), d, dt),
        "wk": dense_init(kk, (d, kvd), d, dt),
        "wv": dense_init(kv, (d, kvd), d, dt),
        "wo": dense_init(ko, (qd, d), qd, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm(cfg, cfg.head_dim)
        p["k_norm"] = init_norm(cfg, cfg.head_dim)
    return p


CHUNKED_ATTN_THRESHOLD = 8192   # chunk prefill queries beyond this length


def _attend_block(qg, k, v, cfg, q_pos, k_pos, k_valid, causal, window, dt):
    """One (q-block) x (full kv) attention.  qg: (B,cq,Hk,G,D).

    q_pos/k_pos/k_valid may be shared across the batch — q_pos (S,),
    k_pos (T,), k_valid (T,) — or per-batch-element — (B,S)/(B,T)/(B,T) —
    for per-slot continuous batching where every slot sits at its own
    decode position.  The mask math broadcasts over either layout."""
    b, cq = qg.shape[:2]
    hd = qg.shape[-1]
    rel = q_pos[..., :, None] - k_pos[..., None, :]   # (S,T) or (B,S,T)
    ok = jnp.ones(rel.shape, bool)
    if causal:
        ok &= rel >= 0
    if window and window > 0:
        ok &= rel < window
    if k_valid is not None:
        ok &= k_valid[..., None, :]
    bias = jnp.where(ok, 0.0, -1e30)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    if cfg.attn_logit_softcap > 0:
        c = cfg.attn_logit_softcap
        scores = c * jnp.tanh(scores / c)
    if bias.ndim == 3:                 # per-batch mask -> (B,1,1,S,T)
        bias = bias[:, None, None]
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(dt), v.astype(dt))
    return out.reshape(b, cq, -1)


def _attend(q, k, v, cfg: ModelConfig, *, q_pos, k_pos, k_valid, causal,
            window, dt):
    """Core GQA attention with position-based masking.

    q: (B,S,H,D); k,v: (B,T,Hkv,D); q_pos: (S,) absolute query positions;
    k_pos: (T,) absolute key positions; k_valid: (T,) bool or None.
    Dispatches to the fused flash kernel on TPU via the dispatch front door
    (repro.backend.dispatch); long-prefill falls back to q-chunked attention
    so the (S,T) score matrix never materializes at full size
    (flash-attention structure, visible to XLA on every backend — §Perf
    jamba iteration 2)."""
    import os

    from repro.backend import dispatch as kops
    b, s, h, hd = q.shape
    hk = k.shape[2]
    groups = h // hk
    batched_pos = q_pos.ndim > 1 or k_pos.ndim > 1

    if not batched_pos and kops.use_flash(cfg, q, k):
        return kops.dispatch_flash_attention(
            q, k, v, q_pos=q_pos, k_pos=k_pos, k_valid=k_valid,
            causal=causal, window=window,
            softcap=cfg.attn_logit_softcap).astype(dt)

    qg = q.reshape(b, s, hk, groups, hd)
    thresh = int(os.environ.get("REPRO_CHUNKED_ATTN",
                                CHUNKED_ATTN_THRESHOLD))
    if not batched_pos and thresh and s > thresh \
            and s % (cq := thresh // 4) == 0:
        nc = s // cq
        qc = jnp.moveaxis(qg.reshape(b, nc, cq, hk, groups, hd), 1, 0)
        pc = q_pos.reshape(nc, cq)

        def body(_, inp):
            q_i, p_i = inp
            o = _attend_block(q_i, k, v, cfg, p_i, k_pos, k_valid,
                              causal, window, dt)
            return None, o
        _, outs = lax.scan(body, None, (qc, pc))
        return jnp.moveaxis(outs, 0, 1).reshape(b, s, h * hd)

    return _attend_block(qg, k, v, cfg, q_pos, k_pos, k_valid, causal,
                         window, dt)


def ring_k_positions(last, W: int):
    """Absolute position of every row of a ring cache whose newest token
    sits at position ``last`` — a scalar, or (B, 1) for per-slot decode.
    Returns (k_pos, k_valid): rows not yet written get negative positions
    and are masked.  This is THE ring invariant; both the lock-step and
    the per-slot decode paths must read the cache through it."""
    i = jnp.arange(W)
    k_pos = last - ((last - i) % W)
    return k_pos, k_pos >= 0


def _paged_write(kv_cache, pages, rows, k, v):
    """Scatter fresh K/V rows into pool pages, quantizing on int8 pools.

    ``pages``/``rows`` index physical (page, row) per fresh token —
    shapes (B,), (B, S), or (S,) matching ``k``/``v``'s leading dims; the
    trailing dims are (Hkv, D).  Out-of-range pages drop the write.
    Returns the new pool-leaf dict (scale leaves updated alongside the
    int8 rows so dequant always sees matching data)."""
    cdt = kv_cache["k_pages"].dtype
    if "k_scales" in kv_cache:
        from repro.kernels import ref as R
        kq, ks = R.quantize_int8_rows(k)
        vq, vs = R.quantize_int8_rows(v)
        return {
            "k_pages": kv_cache["k_pages"].at[pages, rows].set(
                kq, mode="drop"),
            "v_pages": kv_cache["v_pages"].at[pages, rows].set(
                vq, mode="drop"),
            "k_scales": kv_cache["k_scales"].at[pages, rows].set(
                ks, mode="drop"),
            "v_scales": kv_cache["v_scales"].at[pages, rows].set(
                vs, mode="drop"),
        }
    return {"k_pages": kv_cache["k_pages"].at[pages, rows].set(
                k.astype(cdt), mode="drop"),
            "v_pages": kv_cache["v_pages"].at[pages, rows].set(
                v.astype(cdt), mode="drop")}


def _scale_kw(cache):
    """Dequant-scale kwargs for the paged dispatch calls (empty on fp)."""
    if "k_scales" in cache:
        return {"k_scales": cache["k_scales"], "v_scales": cache["v_scales"]}
    return {}


def cross_kv(p, enc_out, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output (cached once at
    prefill so decode steps skip the projections)."""
    b, t, _ = enc_out.shape
    hk, hd = cfg.num_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,dq->bsq", enc_out, p["wk"],
                   preferred_element_type=jnp.float32)
    v = jnp.einsum("bsd,dq->bsq", enc_out, p["wv"],
                   preferred_element_type=jnp.float32)
    return (k.astype(enc_out.dtype).reshape(b, t, hk, hd),
            v.astype(enc_out.dtype).reshape(b, t, hk, hd))


def multi_head_attention(p, x, cfg: ModelConfig, *, positions=None,
                         causal=True, window=0, kv_cache=None,
                         cache_index=None, kv_source=None, use_rope=True,
                         precomputed_kv=None, attend_cache=False,
                         block_tables=None, write_tables=None):
    """General attention supporting GQA, RoPE/M-RoPE, logit softcap, sliding
    window (ring-buffer cache), cross-attention (``kv_source``), and KV-cache
    prefill/decode.

    Modes:
      * train:   kv_cache is None — full attention over x itself.
      * prefill: kv_cache given, x length > 1 — attend over fresh k/v and
                 write the (window-)tail into the cache.
      * chunked prefill continuation: kv_cache given, x length > 1 AND
                 ``attend_cache=True`` — the fresh tokens additionally
                 attend the tokens already sitting in the cache (scalar
                 ``cache_index`` = their count), so a prompt can stream
                 through the stack as consecutive chunks.
      * decode:  kv_cache given, x length small — read/modify/write cache.

    kv_cache: {"k": (B, W, Hkv, D), "v": ...} where W is max_seq for global
    attention or the window size (ring buffer) for local attention — OR a
    *paged* cache {"k_pages": (N, P, Hkv, D), "v_pages": ...}: a physical
    block pool shared by all slots, addressed through ``block_tables``
    ((B, max_blocks) int32, unmapped entries out of range).  Paged caches
    serve two modes: per-slot decode (one token per slot at its own
    position — the write lands in the slot's current page row and the
    attend gathers pages through the table, ``dispatch_paged_attention``)
    and batch-1 suffix/chunk prefill (scalar ``cache_index`` = tokens
    already cached, x = the fresh chunk: K/V are scattered straight into
    the pool rows named by ``write_tables`` — sentinel entries drop the
    write, protecting shared prefix blocks — then every query attends the
    full mapped prefix through ``block_tables`` under a causal mask,
    ``dispatch_paged_prefill_attention``).  ``write_tables`` defaults to
    ``block_tables`` when the whole table is writable (cold prefill with
    no shared blocks).
    cache_index: tokens already in the cache — a scalar int when the whole
    batch decodes in lock-step, or a (B,) vector for per-slot continuous
    batching (each slot writes its own cache row, attends under its own
    length mask, and rotates RoPE at its own position).
    Returns (out, new_kv_cache_or_None).
    """
    b, s, _ = x.shape
    h, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype

    q = jnp.einsum("bsd,dq->bsq", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(dt)
    q = q.reshape(b, s, h, hd)
    if precomputed_kv is not None:
        k, v = precomputed_kv
        kv_source = k          # marks cross-attention (no rope on k, no causal)
    else:
        kv_in = x if kv_source is None else kv_source
        k = jnp.einsum("bsd,dq->bsq", kv_in, p["wk"],
                       preferred_element_type=jnp.float32).astype(dt)
        v = jnp.einsum("bsd,dq->bsq", kv_in, p["wv"],
                       preferred_element_type=jnp.float32).astype(dt)
        k = k.reshape(b, kv_in.shape[1], hk, hd)
        v = v.reshape(b, kv_in.shape[1], hk, hd)

    if "q_norm" in p:
        q = apply_norm(p["q_norm"], q, cfg)
        k = apply_norm(p["k_norm"], k, cfg)

    offset = 0 if cache_index is None else cache_index
    per_slot = cache_index is not None \
        and getattr(cache_index, "ndim", 0) == 1       # (B,) slot positions
    if per_slot:
        pos_bs = offset[:, None] + jnp.arange(s)[None, :]         # (B,S)
    # fused paged decode: RoPE + page-write + attention run as ONE kernel
    # (``dispatch_fused_paged_decode``) when the step is a plain per-slot
    # paged decode rotating at its own cache position — the rotation then
    # happens in-kernel, so the early apply_rope below is skipped and q/k
    # reach the dispatch un-roped.  Anything fancier (M-RoPE, explicit
    # positions, verify windows, rope-free families) keeps the unfused
    # sequence.
    fuse_decode = (kv_cache is not None and "k_pages" in kv_cache
                   and s == 1 and per_slot and kv_source is None
                   and use_rope and cfg.rope_theta > 0
                   and not cfg.mrope_sections and positions is None
                   and not window and block_tables is not None)
    if positions is None:
        if per_slot:
            positions = pos_bs
        else:
            base = offset + jnp.arange(s)[None, :]
            positions = jnp.broadcast_to(base, (b, s))
    if use_rope and cfg.rope_theta > 0 and not fuse_decode:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        if kv_source is None:
            k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)

    causal = causal and kv_source is None
    q_pos = pos_bs if per_slot else jnp.arange(s) + offset

    if kv_cache is None:
        out = _attend(q, k, v, cfg, q_pos=q_pos, k_pos=jnp.arange(k.shape[1]),
                      k_valid=None, causal=causal, window=window, dt=dt)
        new_cache = None
    elif "k_pages" in kv_cache:
        if window:
            raise NotImplementedError(
                "sliding-window attention keeps its dense ring cache "
                "(ring wrap order is position-, not block-, aligned)")
        if block_tables is None:
            raise NotImplementedError(
                "paged KV caches are addressed through block_tables "
                "(per-slot decode or batch-1 suffix/chunk prefill)")
        page = kv_cache["k_pages"].shape[1]
        from repro.backend import dispatch as kops
        if s == 1 and per_slot and fuse_decode:
            # ---- fused paged decode: RoPE (q and fresh k), the page
            # write, and the attention gather run as one dispatch — one
            # HBM round-trip over the pool instead of three.  Same
            # length-masked logical-ordered semantics as the unfused
            # path below (the ref composes exactly that sequence), so fp
            # parity stays bit-exact.
            out, nkp, nvp, nks, nvs = kops.dispatch_fused_paged_decode(
                q, k, v, kv_cache["k_pages"], kv_cache["v_pages"],
                block_tables, offset, theta=cfg.rope_theta,
                softcap=cfg.attn_logit_softcap,
                k_scales=kv_cache.get("k_scales"),
                v_scales=kv_cache.get("v_scales"))
            new_cache = {"k_pages": nkp, "v_pages": nvp}
            if nks is not None:
                new_cache["k_scales"] = nks
                new_cache["v_scales"] = nvs
            out = out.astype(dt)
        elif s == 1 and per_slot:
            # ---- paged decode: the slot's fresh K/V lands in its
            # current page row (table lookup; out-of-range pages drop the
            # write, so idle slots riding along at fixed shape touch
            # nothing), then attention gathers K/V through the block
            # table.  The gathered layout is logical-ordered, so the
            # per-slot length mask reproduces the dense masking exactly —
            # paged decode is bit-identical to dense decode (see
            # kernels/ref.py).
            blk_idx = jnp.clip(offset // page, 0, block_tables.shape[1] - 1)
            pages = jnp.take_along_axis(block_tables, blk_idx[:, None],
                                        axis=1)[:, 0]
            rows = offset % page
            new_cache = _paged_write(kv_cache, pages, rows, k[:, 0], v[:, 0])
            out = kops.dispatch_paged_attention(
                q, new_cache["k_pages"], new_cache["v_pages"],
                block_tables, offset + 1, softcap=cfg.attn_logit_softcap,
                **_scale_kw(new_cache)).astype(dt)
        elif per_slot:
            # ---- paged speculative verify: each slot writes an S-token
            # window (current token + drafted tokens) at its own
            # positions — page lookups per window element, out-of-range
            # blocks drop the write — then every window query attends
            # the slot's full mapped prefix through the block table
            # under a per-slot causal mask.  Greedy argmax over each
            # position then scores the drafts exactly as S sequential
            # one-token decodes would (rejected rows are causally masked
            # everywhere and overwritten by the next window before they
            # could become valid).
            nb = block_tables.shape[1]
            n = kv_cache["k_pages"].shape[0]
            blk = pos_bs // page                                    # (B,S)
            pages = jnp.where(
                blk < nb,
                jnp.take_along_axis(block_tables,
                                    jnp.clip(blk, 0, nb - 1), axis=1), n)
            rows = pos_bs % page
            new_cache = _paged_write(kv_cache, pages, rows, k, v)
            out = kops.dispatch_paged_verify_attention(
                q, new_cache["k_pages"], new_cache["v_pages"],
                block_tables, offset, softcap=cfg.attn_logit_softcap,
                **_scale_kw(new_cache)).astype(dt)
        else:
            # ---- paged suffix/chunk prefill: write the fresh chunk's
            # K/V straight into the pool (write_tables names each fresh
            # block's physical page; sentinel entries — shared prefix
            # blocks and pad positions past the mapped range — drop the
            # write), then attend ALL mapped positions through the block
            # table under a pure causal mask.  A warm suffix thereby
            # attends the reused prefix without ever recomputing it.
            if b != 1:
                raise NotImplementedError(
                    "paged prefill writes through one write-table row; "
                    "batch the chunks, not the slots")
            wt = block_tables if write_tables is None else write_tables
            n = kv_cache["k_pages"].shape[0]
            nb = wt.shape[1]
            pos = offset + jnp.arange(s)
            blk = pos // page
            rows = pos % page
            # pad positions can run past the table; clipping must not
            # alias them onto the last real block, so map them to the
            # drop sentinel explicitly.
            phys = jnp.where(blk < nb, wt[0, jnp.clip(blk, 0, nb - 1)], n)
            new_cache = _paged_write(kv_cache, phys, rows, k[0], v[0])
            out = kops.dispatch_paged_prefill_attention(
                q, new_cache["k_pages"], new_cache["v_pages"],
                block_tables, offset, softcap=cfg.attn_logit_softcap,
                **_scale_kw(new_cache)).astype(dt)
    else:
        W = kv_cache["k"].shape[1]
        cdt = kv_cache["k"].dtype
        if s > 1 and not per_slot:
            if attend_cache:
                # ---- chunked-prefill continuation: the chunk's queries
                # attend the cached tokens (ring rows at their absolute
                # positions, unwritten rows masked) AND the fresh k/v.
                # For a global cache the ring is position-ordered, so the
                # valid keys appear in exactly the order the one-shot
                # prefill sums them — chunking is numerically lossless.
                k_pos_old, k_valid_old = ring_k_positions(offset - 1, W)
                k_all = jnp.concatenate([kv_cache["k"].astype(dt), k], axis=1)
                v_all = jnp.concatenate([kv_cache["v"].astype(dt), v], axis=1)
                k_pos_all = jnp.concatenate(
                    [k_pos_old, offset + jnp.arange(s)])
                k_valid_all = jnp.concatenate(
                    [k_valid_old, jnp.ones((s,), bool)])
                out = _attend(q, k_all, v_all, cfg, q_pos=q_pos,
                              k_pos=k_pos_all, k_valid=k_valid_all,
                              causal=causal, window=window, dt=dt)
            else:
                # ---- prefill: attend over the fresh full-length k/v ----
                out = _attend(q, k, v, cfg, q_pos=q_pos,
                              k_pos=jnp.arange(k.shape[1]), k_valid=None,
                              causal=causal, window=window, dt=dt)
            # write the last min(s, W) tokens into (ring) cache slots.
            tail = min(s, W)
            k_tail = k[:, s - tail:].astype(cdt)
            v_tail = v[:, s - tail:].astype(cdt)
            tail_pos = offset + jnp.arange(s - tail, s)
            slots = tail_pos % W
            new_k = kv_cache["k"].at[:, slots].set(k_tail)
            new_v = kv_cache["v"].at[:, slots].set(v_tail)
            new_cache = {"k": new_k, "v": new_v}
        elif per_slot:
            # ---- per-slot decode: each batch row writes its own cache
            # row(s) and attends under its own length mask (slots sit at
            # different positions under continuous batching).  s > 1 is
            # the speculative-verify window: S rows land at the slot's
            # own positions and the per-query causal mask scores each
            # window position exactly as S sequential decodes would ----
            rows = pos_bs % W                                       # (B,S)
            bidx = jnp.arange(b)[:, None]
            new_k = kv_cache["k"].at[bidx, rows].set(k.astype(cdt))
            new_v = kv_cache["v"].at[bidx, rows].set(v.astype(cdt))
            new_cache = {"k": new_k, "v": new_v}
            k_pos, k_valid = ring_k_positions(
                (offset + s - 1)[:, None], W)        # (B,W) per-slot mask
            out = _attend(q, new_k, new_v, cfg, q_pos=q_pos, k_pos=k_pos,
                          k_valid=k_valid, causal=causal, window=window,
                          dt=dt)
        else:
            # ---- decode: ring write then attend over the cache ----
            slots = (offset + jnp.arange(s)) % W
            new_k = kv_cache["k"].at[:, slots].set(k.astype(cdt))
            new_v = kv_cache["v"].at[:, slots].set(v.astype(cdt))
            new_cache = {"k": new_k, "v": new_v}
            k_pos, k_valid = ring_k_positions(offset + s - 1, W)
            out = _attend(q, new_k, new_v, cfg, q_pos=q_pos, k_pos=k_pos,
                          k_valid=k_valid, causal=causal, window=window,
                          dt=dt)

    out = jnp.einsum("bsq,qd->bsd", out, p["wo"],
                     preferred_element_type=jnp.float32).astype(dt)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def _act(name):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True),
            "relu2": lambda x: jnp.square(jax.nn.relu(x))}[name]


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 3)
    p = {"wi": dense_init(ks[0], (cfg.d_model, d_ff), cfg.d_model, dt),
         "wo": dense_init(ks[1], (d_ff, cfg.d_model), d_ff, dt)}
    if cfg.gated_mlp:
        p["wg"] = dense_init(ks[2], (cfg.d_model, d_ff), cfg.d_model, dt)
    return p


def apply_mlp(p, x, cfg: ModelConfig):
    dt = x.dtype
    hid = jnp.einsum("bsd,df->bsf", x, p["wi"],
                     preferred_element_type=jnp.float32)
    act = _act(cfg.mlp_activation)
    if "wg" in p:
        gate = jnp.einsum("bsd,df->bsf", x, p["wg"],
                          preferred_element_type=jnp.float32)
        hid = act(gate) * hid
    else:
        hid = act(hid)
    out = jnp.einsum("bsf,fd->bsd", hid.astype(dt), p["wo"],
                     preferred_element_type=jnp.float32)
    return out.astype(dt)


def init_moe(key, cfg: ModelConfig):
    moe: MoEConfig = cfg.moe
    dt = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 6)
    e, d, f = moe.num_experts, cfg.d_model, moe.expert_d_ff
    p = {
        "router": dense_init(ks[0], (d, e), d, jnp.float32),
        "wi": dense_init(ks[1], (e, d, f), d, dt),
        "wg": dense_init(ks[2], (e, d, f), d, dt),
        "wo": dense_init(ks[3], (e, f, d), f, dt),
    }
    if moe.num_shared_experts:
        sf = moe.shared_expert_d_ff
        p["shared"] = {
            "wi": dense_init(ks[4], (d, sf), d, dt),
            "wg": dense_init(ks[5], (d, sf), d, dt),
            "wo": dense_init(ks[4], (sf, d), sf, dt),
        }
    return p


def apply_moe(p, x, cfg: ModelConfig):
    """GShard-style top-k capacity-limited dispatch (scatter/gather, no
    (N,E,C) dense dispatch tensor): FLOPs scale with tokens*k*capacity_factor,
    which keeps MODEL_FLOPS/HLO_FLOPS honest for the roofline."""
    moe: MoEConfig = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = moe.num_experts, moe.experts_per_token
    # capacity per choice-round: each token contributes ONE slot per round,
    # so a round routes n slots over e experts (not n*k — that would k²-
    # inflate the expert matmul FLOPs).
    cap = max(1, int(math.ceil(n * moe.capacity_factor / e)))
    if s == 1:
        # decode: drop-free capacity.  Capacity drops couple batch rows
        # (tokens compete for expert rows via the routing cumsum), which
        # would make one serving slot's next token depend on what the
        # OTHER slots decoded — breaking the continuous-batching parity
        # guarantee.  n is tiny at decode, so the extra rows are free.
        cap = n
    dt = x.dtype
    xf = x.reshape(n, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = lax.top_k(probs, k)                       # (n, k)
    top_w = top_w / jnp.sum(top_w, -1, keepdims=True)

    # position-in-expert via per-round cumulative counts (GShard Alg.1,
    # with one dispatch buffer per choice round).
    pos_list, keep_list = [], []
    for j in range(k):
        onehot = jax.nn.one_hot(top_e[:, j], e, dtype=jnp.int32)   # (n, e)
        pos = jnp.cumsum(onehot, axis=0) - 1
        pos_j = jnp.sum(pos * onehot, axis=-1)                     # (n,)
        keep_list.append(pos_j < cap)
        pos_list.append(jnp.clip(pos_j, 0, cap - 1))

    y = jnp.zeros((n, d), jnp.float32)
    # dispatch buffers (e, cap, d) per choice are scatter-filled then
    # expert-matmul'd; combine gathers back with routing weights.
    for j in range(k):
        idx_e = top_e[:, j]
        idx_c = pos_list[j]
        keep = keep_list[j]
        buf = jnp.zeros((e, cap, d), dt)
        src = jnp.where(keep[:, None], xf, 0).astype(dt)
        buf = buf.at[idx_e, idx_c].add(src, mode="drop")
        hid = jnp.einsum("ecd,edf->ecf", buf, p["wi"],
                         preferred_element_type=jnp.float32)
        gate = jnp.einsum("ecd,edf->ecf", buf, p["wg"],
                          preferred_element_type=jnp.float32)
        hid = (jax.nn.silu(gate) * hid).astype(dt)
        out = jnp.einsum("ecf,efd->ecd", hid, p["wo"],
                         preferred_element_type=jnp.float32)
        tok_out = out[idx_e, idx_c]                                # (n, d)
        y = y + jnp.where(keep[:, None], tok_out, 0) * top_w[:, j:j + 1]

    if "shared" in p:
        sh = p["shared"]
        hid = jnp.einsum("nd,df->nf", xf, sh["wi"],
                         preferred_element_type=jnp.float32)
        gate = jnp.einsum("nd,df->nf", xf, sh["wg"],
                          preferred_element_type=jnp.float32)
        hid = (jax.nn.silu(gate) * hid).astype(dt)
        y = y + jnp.einsum("nf,fd->nd", hid, sh["wo"],
                           preferred_element_type=jnp.float32)

    # auxiliary load-balance loss (Switch): stored for the training loop.
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)
    return y.reshape(b, s, d).astype(dt), aux


# ---------------------------------------------------------------------------
# embeddings / heads
# ---------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.param_dtype)
    return {"table": dense_init(key, (cfg.vocab_size, cfg.d_model), cfg.d_model, dt)}


def embed(p, ids, cfg: ModelConfig):
    out = jnp.take(p["table"], ids, axis=0)
    if cfg.family == "dense" and cfg.tie_embeddings:
        out = out * jnp.sqrt(jnp.float32(cfg.d_model)).astype(out.dtype)
    return out


def chunked_softmax_xent(x, w, labels, cfg: ModelConfig, *,
                         chunk: int = 8192):
    """Cross-entropy fused with the LM head over vocab chunks: the
    (tokens × vocab) logits tensor never materializes — an online
    logsumexp runs across vocab chunks (flash-softmax structure applied to
    the loss; the dominant train activation for 256k-vocab archs).

    x: (N, D) final hidden; w: (D, V); labels: (N,) int32 (< 0 = masked).
    Returns per-token nll (N,)."""
    n, d = x.shape
    v = w.shape[1]
    if v % chunk:
        chunk = v  # fallback: single chunk
    n_chunks = v // chunk
    wc = w.T.reshape(n_chunks, chunk, d)      # (C, chunk, D)
    cap = cfg.final_logit_softcap
    xf = x.astype(jnp.float32)

    def body(carry, inp):
        m, l, tgt = carry
        w_c, c_idx = inp
        logits = jnp.einsum("nd,cd->nc", xf, w_c.astype(jnp.float32))
        if cap > 0:
            logits = cap * jnp.tanh(logits / cap)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        l = l * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[:, None]), axis=-1)
        # pick up the target logit if it lives in this chunk
        local = labels - c_idx * chunk
        in_chunk = (local >= 0) & (local < chunk)
        got = jnp.take_along_axis(
            logits, jnp.clip(local, 0, chunk - 1)[:, None], axis=-1)[:, 0]
        tgt = jnp.where(in_chunk, got, tgt)
        return (m_new, l, tgt), None

    body = jax.checkpoint(body)   # recompute chunk logits in bwd
    m0 = jnp.full((n,), -1e30, jnp.float32)
    l0 = jnp.zeros((n,), jnp.float32)
    t0 = jnp.zeros((n,), jnp.float32)
    (m, l, tgt), _ = lax.scan(body, (m0, l0, t0),
                              (wc, jnp.arange(n_chunks)))
    lse = m + jnp.log(l)
    return lse - tgt                          # (N,) nll


def logits_head(p_embed, p_head, x, cfg: ModelConfig):
    if cfg.tie_embeddings or p_head is None:   # (whisper ties decoder embed)
        w = p_embed["table"].T
    else:
        w = p_head["w"]
    out = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=jnp.float32)
    if cfg.final_logit_softcap > 0:
        c = cfg.final_logit_softcap
        out = c * jnp.tanh(out / c)
    return out
