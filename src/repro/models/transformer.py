"""Block assembly and full-model forward passes.

The layer stack is organized as ``num_groups`` repetitions of the config's
``block_pattern`` (one period).  Per-position parameters are stacked with a
leading group axis and the stack is executed with ``jax.lax.scan`` — this
keeps the lowered HLO size O(pattern) instead of O(num_layers), which is what
makes 80-layer 72B dry-run compiles tractable and is also the deployment-
grade structure (MaxText-style).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import BlockSpec, ModelConfig
from repro.models import layers as L
from repro.models import ssm as S

# ---------------------------------------------------------------------------
# sharding constraint helper (mesh-agnostic: no-op without an ambient mesh)
# ---------------------------------------------------------------------------

BATCH_AXES = ("pod", "data")


def constrain(x, spec_axes):
    """Apply a sharding constraint using only axes present in the ambient
    abstract mesh.  spec_axes: tuple of axis-name-or-None per dim."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if mesh is None or not mesh.axis_names:
        return x
    names = set(mesh.axis_names)

    def _filt(a):
        if a is None:
            return None
        if isinstance(a, tuple):
            kept = tuple(n for n in a if n in names)
            return kept if kept else None
        return a if a in names else None

    spec = P(*[_filt(a) for a in spec_axes])
    return jax.lax.with_sharding_constraint(x, spec)


def shard_act(x):
    """(B, S, D) activations: batch over (pod,data); with REPRO_SP=1 the
    residual stream is additionally SEQUENCE-sharded over 'model' between
    blocks (Megatron sequence parallelism): norms/elementwise run on 1/tp of
    the tokens and the per-block TP all-reduce becomes reduce-scatter +
    all-gather — half the ICI traffic (§Perf iteration)."""
    import os
    if x.ndim == 3:
        if os.environ.get("REPRO_SP", "0") == "1" and x.shape[1] > 1:
            try:
                mesh = jax.sharding.get_abstract_mesh()
                if mesh is not None and "model" in (mesh.axis_names or ()) \
                        and x.shape[1] % mesh.shape["model"] == 0:
                    return constrain(x, (BATCH_AXES, "model", None))
            except Exception:
                pass
        return constrain(x, (BATCH_AXES, None, None))
    return x


# ---------------------------------------------------------------------------
# per-block init / apply
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, blk: BlockSpec, cross: bool = False):
    ks = L.split_keys(key, 4)
    p: Dict[str, Any] = {"norm1": L.init_norm(cfg)}
    m = blk.mixer
    if m.startswith("attn"):
        p["mixer"] = L.init_attention(ks[0], cfg)
    elif m == "mamba":
        p["mixer"] = S.init_mamba(ks[0], cfg)
    elif m == "mlstm":
        p["mixer"] = S.init_mlstm(ks[0], cfg)
    elif m == "slstm":
        p["mixer"] = S.init_slstm(ks[0], cfg)
    else:
        raise ValueError(m)
    if cross:
        p["norm_x"] = L.init_norm(cfg)
        p["cross"] = L.init_attention(ks[3], cfg, cross=True)
    if blk.ffn != "none":
        p["norm2"] = L.init_norm(cfg)
        p["ffn"] = (L.init_moe(ks[1], cfg) if blk.ffn == "moe"
                    else L.init_mlp(ks[1], cfg))
    if cfg.post_block_norm:
        p["post_norm1"] = L.init_norm(cfg)
        if blk.ffn != "none":
            p["post_norm2"] = L.init_norm(cfg)
    return p


def apply_block(p, x, cfg: ModelConfig, blk: BlockSpec, *,
                positions=None, causal=True, state=None, cache_index=None,
                enc_out=None, attend_cache=False, block_tables=None,
                write_tables=None):
    """Returns (x, new_state, aux_loss)."""
    m = blk.mixer
    h = L.apply_norm(p["norm1"], x, cfg)
    new_state = None
    if m.startswith("attn"):
        window = cfg.window_size if m == "attn_local" else 0
        attn_cache = state.get("kv") if state else None
        h, new_kv = L.multi_head_attention(
            p["mixer"], h, cfg, positions=positions, causal=causal,
            window=window, kv_cache=attn_cache, cache_index=cache_index,
            attend_cache=attend_cache, block_tables=block_tables,
            write_tables=write_tables)
        new_state = {"kv": new_kv} if new_kv is not None else None
    elif m == "mamba":
        h, st = S.apply_mamba(p["mixer"], h, cfg,
                              state=state.get("ssm_state") if state else None)
        new_state = {"ssm_state": st}
    elif m == "mlstm":
        h, st = S.apply_mlstm(p["mixer"], h, cfg,
                              state=state.get("ssm_state") if state else None)
        new_state = {"ssm_state": st}
    elif m == "slstm":
        h, st = S.apply_slstm(p["mixer"], h, cfg,
                              state=state.get("ssm_state") if state else None)
        new_state = {"ssm_state": st}
    if cfg.post_block_norm:
        h = L.apply_norm(p["post_norm1"], h, cfg)
    x = shard_act(x + h)

    if "cross" in p:
        h = L.apply_norm(p["norm_x"], x, cfg)
        # enc_out present => training/prefill: compute cross-K/V fresh from
        # the encoder (and cache it).  Only decode (no enc_out) may use the
        # cached cross_kv — a zero-initialized prefill cache must NOT
        # shadow the encoder (XLA would DCE the whole encoder).
        if enc_out is not None:
            h, _ = L.multi_head_attention(
                p["cross"], h, cfg, causal=False, kv_source=enc_out,
                use_rope=False)
            if new_state is not None:
                # prefill: cache cross K/V so decode skips the projections.
                ck, cv = L.cross_kv(p["cross"], enc_out, cfg)
                new_state["cross_kv"] = {"k": ck, "v": cv}
        elif state is not None and "cross_kv" in state:
            pkv = state["cross_kv"]
            h, _ = L.multi_head_attention(
                p["cross"], h, cfg, causal=False, use_rope=False,
                precomputed_kv=(pkv["k"], pkv["v"]))
            if new_state is not None:
                new_state["cross_kv"] = pkv
        else:
            raise ValueError("cross-attention block needs enc_out or cache")
        x = shard_act(x + h)

    aux = jnp.zeros((), jnp.float32)
    if blk.ffn != "none":
        h = L.apply_norm(p["norm2"], x, cfg)
        if blk.ffn == "moe":
            h, aux = L.apply_moe(p["ffn"], h, cfg)
        else:
            h = L.apply_mlp(p["ffn"], h, cfg)
        if cfg.post_block_norm:
            h = L.apply_norm(p["post_norm2"], h, cfg)
        x = shard_act(x + h)
    return x, new_state, aux


# ---------------------------------------------------------------------------
# state (KV-cache / recurrent) shape bookkeeping
# ---------------------------------------------------------------------------

def block_state_shapes(cfg: ModelConfig, blk: BlockSpec, batch: int,
                       max_seq: int, enc_len: int = 0) -> Dict[str, Any]:
    m = blk.mixer
    out: Dict[str, Any] = {}
    if m.startswith("attn"):
        kv_len = min(max_seq, cfg.window_size) if m == "attn_local" else max_seq
        shp = (batch, kv_len, cfg.num_kv_heads, cfg.head_dim)
        out["kv"] = {"k": shp, "v": shp}
    elif m == "mamba":
        out["ssm_state"] = S.mamba_state_shape(cfg, batch)
    elif m == "mlstm":
        out["ssm_state"] = S.mlstm_state_shape(cfg, batch)
    elif m == "slstm":
        out["ssm_state"] = S.slstm_state_shape(cfg, batch)
    else:
        raise ValueError(m)
    if enc_len:
        shp = (batch, enc_len, cfg.num_kv_heads, cfg.head_dim)
        out["cross_kv"] = {"k": shp, "v": shp}
    return out


def _state_leaf_dtype(cfg: ModelConfig, blk: BlockSpec, key: str, dtype):
    if key == "kv" or key == "cross_kv" or blk.mixer.startswith("attn"):
        return dtype
    return jnp.float32


def _dense_block_leaves(cfg: ModelConfig, blk: BlockSpec, batch: int,
                        max_seq: int, enc_len: int, dt, factory):
    """One pattern slot's dense state leaves (group axis leading) — the
    shared builder behind ``make_cache`` and ``make_paged_cache``'s
    non-paged branch, so dtype rules and shapes cannot drift between the
    two layouts."""
    shapes = block_state_shapes(cfg, blk, batch, max_seq, enc_len)
    sub = {}
    for key, val in shapes.items():
        leaf_dt = dt if key in ("kv", "cross_kv") else jnp.float32
        sub[key] = jax.tree.map(
            lambda shp, d=leaf_dt: factory((cfg.num_groups,) + shp, d),
            val, is_leaf=lambda x: isinstance(x, tuple))
    return sub


def make_cache(cfg: ModelConfig, batch: int, max_seq: int, *, enc_len: int = 0,
               dtype=None, factory=None):
    """Decode cache pytree, stacked over groups per pattern slot.

    factory(shape, dtype) -> leaf;  defaults to jnp.zeros (concrete cache);
    pass jax.ShapeDtypeStruct for dry-run specs."""
    dt = jnp.dtype(dtype or cfg.dtype)
    factory = factory or jnp.zeros
    return {f"b{j}": _dense_block_leaves(cfg, blk, batch, max_seq, enc_len,
                                         dt, factory)
            for j, blk in enumerate(cfg.block_pattern)}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None,
               enc_len: int = 0):
    return make_cache(cfg, batch, max_seq, enc_len=enc_len, dtype=dtype)


def _is_global_attn(mixer: str) -> bool:
    """Global-attention mixers ("attn" / "attn_global") hold pageable
    max_seq KV; "attn_local" keeps a ring."""
    return mixer.startswith("attn") and mixer != "attn_local"


def has_paged_layers(cfg: ModelConfig) -> bool:
    """Whether the pattern has any global-attention KV to page.  Sliding-
    window rings (their ring wrap is position-, not block-, ordered) and
    recurrent SSM state (O(1) per slot already) always stay dense."""
    return any(_is_global_attn(b.mixer) for b in cfg.block_pattern)


def make_paged_cache(cfg: ModelConfig, batch: int, max_seq: int, *,
                     page_size: int, num_blocks: int, dtype=None,
                     factory=None, kv_dtype: str = "fp"):
    """Decode cache where global-attention KV lives in a physical block
    pool instead of a dense per-slot reservation.

    Global-attn leaves become page pools shaped
    ``(num_groups, num_blocks + 1, page_size, kv_heads, head_dim)``
    shared by all ``batch`` slots and addressed through per-slot block
    tables (``repro.cache.PagedCacheManager``); every other leaf —
    local-window rings, mamba/mlstm/slstm state — keeps the dense
    ``(num_groups, batch, ...)`` slot layout of ``make_cache`` (paging
    auto-disables for them).  The group axis stays leading, so the plan
    runtime's ``slice_cache_groups`` stage slicing works unchanged on
    paged caches.

    The extra physical page is the **write sink**: the manager's
    unmapped-block sentinel is ``num_blocks``, which indexes it — an
    in-range page no table ever maps for reads, so code paths that
    cannot *drop* a sentinel write (Pallas output index maps have no
    drop mode) land it there harmlessly instead.  Capacity accounting
    stays in ``num_blocks`` (the sink is never allocatable).

    kv_dtype: "fp" stores K/V at ``dtype``; "int8" stores int8 rows
    plus per-(row, kv-head) f32 dequant scales (``k_scales``/
    ``v_scales`` leaves, shaped pool[:-1]) — ~``head_dim *
    itemsize / (head_dim + 4)``× more tokens per byte
    (``paged_kv_capacity_ratio``)."""
    dt = jnp.dtype(dtype or cfg.dtype)
    factory = factory or jnp.zeros
    if max_seq % page_size:
        raise ValueError(f"max_seq={max_seq} must be a multiple of "
                         f"page_size={page_size}")
    if kv_dtype not in ("fp", "int8"):
        raise ValueError(f"kv_dtype={kv_dtype!r} must be 'fp' or 'int8'")
    cache = {}
    for j, blk in enumerate(cfg.block_pattern):
        if _is_global_attn(blk.mixer):
            shp = (cfg.num_groups, num_blocks + 1, page_size,
                   cfg.num_kv_heads, cfg.head_dim)
            if kv_dtype == "int8":
                cache[f"b{j}"] = {"kv": {
                    "k_pages": factory(shp, jnp.int8),
                    "v_pages": factory(shp, jnp.int8),
                    "k_scales": factory(shp[:-1], jnp.float32),
                    "v_scales": factory(shp[:-1], jnp.float32),
                }}
            else:
                cache[f"b{j}"] = {"kv": {"k_pages": factory(shp, dt),
                                         "v_pages": factory(shp, dt)}}
        else:
            cache[f"b{j}"] = _dense_block_leaves(cfg, blk, batch, max_seq,
                                                 0, dt, factory)
    return cache


def paged_kv_capacity_ratio(cfg: ModelConfig, kv_dtype: str,
                            dtype=None) -> float:
    """Tokens-per-byte multiplier of a ``kv_dtype`` pool over the fp
    layout at the same byte budget: int8 rows cost ``head_dim`` bytes
    plus one f32 scale vs ``head_dim * itemsize`` fp bytes — 3.88× for
    f32 / 1.94× for bf16 pools at head_dim 128."""
    if kv_dtype == "fp":
        return 1.0
    dt = jnp.dtype(dtype or cfg.dtype)
    d = cfg.head_dim
    return (d * dt.itemsize) / float(d + 4)


def slice_cache_groups(cache, first_group: int, n_groups: int):
    """A stage-local view of a decode cache: leaves are (num_groups, B, ...)
    so a plan stage's slice is a static gather on axis 0 over its group
    range [first_group, first_group + n_groups) — the serving-side analogue
    of ``pipeline.plan_stage_params`` (but exact, never padded: caches are
    stateful, so dead-group masking does not apply)."""
    return jax.tree.map(lambda l: l[first_group:first_group + n_groups],
                        cache)


def merge_cache_groups(full_cache, part_cache, first_group: int):
    """Write a stage's updated group slice back into the full cache
    (static group range — the inverse of ``slice_cache_groups``)."""
    def leaf(full, part):
        return full.at[first_group:first_group + part.shape[0]].set(
            part.astype(full.dtype))
    return jax.tree.map(leaf, full_cache, part_cache)


def concat_cache_groups(slices):
    """Stitch ordered per-stage cache slices back into a full cache: the
    plan's stages tile the group axis, so concatenation on axis 0 of every
    leaf reassembles exactly ``num_groups`` entries."""
    return jax.tree.map(lambda *ls: jnp.concatenate(ls, axis=0), *slices)


def _block_is_paged(sub) -> bool:
    """Whether one pattern slot's cache entry holds pool-backed KV."""
    return "kv" in sub and isinstance(sub["kv"], dict) \
        and "k_pages" in sub["kv"]


def supports_prefix_compute_reuse(cfg: ModelConfig) -> bool:
    """Whether a warm prefix may skip its prefill *compute* (not just its
    KV memory): every mixer must be global attention (local-window rings
    and recurrent SSM state have no paged per-position cache to resume
    from) and no FFN may be MoE (expert capacity couples the routing of
    every token in a call, so a suffix-only call is not numerically the
    tail of the full-prompt call).  Block-level memory sharing stays on
    for every family regardless."""
    return all(_is_global_attn(b.mixer) and b.ffn != "moe"
               for b in cfg.block_pattern)


def make_prefill_part(cfg: ModelConfig, max_seq: int, *, dtype=None,
                      enc_len: int = 0):
    """The *dense remainder* of a paged prefill: batch-1 state for every
    non-paged pattern slot (SSM state, local-window rings) and an empty
    entry for paged ones — global-attn K/V streams straight into the
    pool, so nothing is staged for it.  Combine with the pool-backed
    cache via ``combine_prefill_parts`` to run the stack, and land the
    dense half with ``scatter_prefill_part``."""
    dt = jnp.dtype(dtype or cfg.dtype)
    return {f"b{j}": ({} if _is_global_attn(blk.mixer)
                      else _dense_block_leaves(cfg, blk, 1, max_seq,
                                               enc_len, dt, jnp.zeros))
            for j, blk in enumerate(cfg.block_pattern)}


def combine_prefill_parts(paged_cache, dense_part):
    """Assemble the cache view a paged prefill runs against: paged blocks
    contribute their live pool leaves (written in place through the write
    tables), every other block its batch-1 dense part."""
    return {bk: (sub if _block_is_paged(sub) else dense_part[bk])
            for bk, sub in paged_cache.items()}


def split_prefill_parts(view, paged_cache):
    """Inverse of ``combine_prefill_parts``: split an updated view into
    (new_paged_cache, new_dense_part).  ``paged_cache`` supplies the
    layout (and the untouched dense slot leaves of the full cache)."""
    new_paged = {bk: (view[bk] if _block_is_paged(sub) else sub)
                 for bk, sub in paged_cache.items()}
    new_part = {bk: ({} if _block_is_paged(sub) else view[bk])
                for bk, sub in paged_cache.items()}
    return new_paged, new_part


def merge_prefill_view(full_cache, new_view, slot):
    """Land a finished paged prefill: paged blocks take the view's pool
    leaves wholesale (the K/V already sits in the right physical pages —
    there is no commit-time copy), dense blocks scatter their batch-1
    part into batch row ``slot``."""
    out = {}
    for bk, sub in full_cache.items():
        if _block_is_paged(sub):
            out[bk] = new_view[bk]
        else:
            out[bk] = jax.tree.map(
                lambda f, p: lax.dynamic_update_slice_in_dim(
                    f, p.astype(f.dtype), slot, axis=1),
                sub, new_view[bk])
    return out


def scatter_prefill_part(full_cache, dense_part, slot):
    """Scatter only the *dense* half of a paged prefill (SSM state,
    local-window rings) into batch row ``slot``; paged blocks pass
    through untouched — their K/V was written directly into the pool as
    the chunks ran."""
    out = {}
    for bk, sub in full_cache.items():
        if _block_is_paged(sub):
            out[bk] = sub
        else:
            out[bk] = jax.tree.map(
                lambda f, p: lax.dynamic_update_slice_in_dim(
                    f, p.astype(f.dtype), slot, axis=1),
                sub, dense_part[bk])
    return out


def copy_cache_pages(full_cache, src, dst):
    """Copy physical block ``src`` onto ``dst`` in every paged KV leaf —
    the device half of copy-on-write (a slot diverging from a shared
    block gets a private copy before its first write).  Dense leaves are
    untouched; ``src``/``dst`` may be traced scalars."""
    def leaf(sub):
        if isinstance(sub, dict) and "k_pages" in sub:
            return {n: p.at[:, dst].set(jnp.take(p, src, axis=1,
                                                 mode="clip"))
                    for n, p in sub.items()}
        return sub
    return {bk: {key: leaf(val) for key, val in s.items()}
            for bk, s in full_cache.items()}


def rebind_pool_leaves(cache, src):
    """Rebind a cache view's paged pool leaves to ``src``'s, keeping its
    own dense slot leaves.  Pure pytree restructuring — no device work.
    This is what lets every decode replica front ONE shared physical
    pool: after a step updates the pool through one replica's cache
    (donating the input buffers), the other replicas' views re-alias the
    fresh pool arrays here instead of copying pages."""
    return {bk: (src[bk] if _block_is_paged(sub) else sub)
            for bk, sub in cache.items()}


def has_dense_slot_leaves(cache) -> bool:
    """Whether the cache keeps any per-slot dense state (SSM state,
    local-window rings).  False for all-global-attention paged caches —
    a slot's entire state then lives behind its block table, so moving a
    request between slots/replicas/plans is pure host bookkeeping."""
    return any(not _block_is_paged(sub) for sub in cache.values())


def slice_cache_slots(cache, first: int, n: int):
    """Slot-axis slice [first, first + n) of a cache: dense leaves slice
    their batch axis (axis 1), paged pool leaves pass through untouched
    (they are slot-agnostic — a slot's paged state is its block-table
    row, not a pool region)."""
    return {bk: (sub if _block_is_paged(sub)
                 else jax.tree.map(lambda l: l[:, first:first + n], sub))
            for bk, sub in cache.items()}


def concat_cache_slots(caches):
    """Inverse of per-replica slot partitioning: concatenate dense leaves
    on the slot axis (axis 1).  Paged pool leaves are SHARED physical
    arrays across the per-replica views, so the first view's are taken
    as-is — re-planning between replica layouts never copies a page."""
    out = {}
    for bk, sub in caches[0].items():
        if _block_is_paged(sub):
            out[bk] = sub
        else:
            out[bk] = jax.tree.map(
                lambda *ls: jnp.concatenate(ls, axis=1),
                *[c[bk] for c in caches])
    return out


def extract_dense_slot(cache, slot):
    """Batch row ``slot`` of the cache's DENSE leaves only, as a part
    cache ({} when the model is all-global-attention paged).  Paged
    blocks are omitted entirely — the result is safe to pass alongside a
    *donated* full cache (``scatter_cache_slot`` / the paged scatter),
    where including shared pool leaves would alias freed buffers.  This
    is the slot-migration read: a slot's paged state moves by block-table
    handoff, only its dense row rides the device."""
    return {bk: jax.tree.map(lambda l: l[:, slot:slot + 1], sub)
            for bk, sub in cache.items() if not _block_is_paged(sub)}


def scatter_cache_slot(full_cache, part_cache, slot):
    """Write a small-batch cache into batch rows [slot, slot+b) of a
    persistent slot-indexed cache, leaving every other slot untouched.

    Cache leaves are (num_groups, batch, ...), so the batch axis is axis 1
    and the write lowers to one ``dynamic_update_slice`` per leaf — the
    admission primitive of per-slot continuous batching (KV rows AND
    recurrent SSM/conv states both live on that axis, so one tree-map
    covers attention, hybrid, and pure-SSM families alike).  ``slot`` may
    be a traced scalar."""
    def leaf(full, part):
        return lax.dynamic_update_slice_in_dim(
            full, part.astype(full.dtype), slot, axis=1)
    return jax.tree.map(leaf, full_cache, part_cache)


# ---------------------------------------------------------------------------
# stacked-parameter init
# ---------------------------------------------------------------------------

def init_stack(key, cfg: ModelConfig, cross: bool = False):
    """Init all groups: returns pytree with leading group axis on leaves."""
    keys = jax.random.split(key, cfg.num_groups)

    def one_group(k):
        ks = L.split_keys(k, len(cfg.block_pattern))
        return {f"b{j}": init_block(ks[j], cfg, blk, cross=cross)
                for j, blk in enumerate(cfg.block_pattern)}
    return jax.vmap(one_group)(keys)


# ---------------------------------------------------------------------------
# stack execution (scan over groups)
# ---------------------------------------------------------------------------

def run_stack(stack_params, x, cfg: ModelConfig, *, positions=None,
              causal=True, cache=None, cache_index=None, enc_out=None,
              remat: bool = False, collect_state: bool = False,
              group_mask=None, attend_cache: bool = False,
              block_tables=None, write_tables=None):
    """Run the whole layer stack.  Returns (x, new_cache, aux_sum).

    collect_state: emit per-group state (KV cache / recurrent state) as scan
    outputs — used by prefill/decode; train leaves it off so SSM states are
    not materialized across groups.

    group_mask: optional (num_groups,) 0/1 vector scanned alongside the
    params; groups with mask 0 pass activations (and aux) through unchanged.
    This is how the ExecutionPlan executor runs *uneven* pipeline stages:
    every stage's stack is padded to the max group count and the dead
    entries are masked here.  Stateless forward only (no cache).

    attend_cache: chunked-prefill continuation — attention blocks attend
    the tokens already in ``cache`` (scalar ``cache_index`` = their count)
    in addition to the fresh chunk; recurrent blocks continue from the
    cached state either way.

    block_tables: (B, max_blocks) int32 logical->physical page map when
    ``cache`` is pool-backed (``make_paged_cache``); shared across groups
    (one table per slot addresses every layer's page pool).

    write_tables: like ``block_tables`` but naming only the blocks this
    call may WRITE (fresh suffix blocks; sentinel elsewhere) — the paged
    prefill path scatters K/V through it so shared prefix blocks are
    never touched.  ``None`` defaults to ``block_tables``."""
    if group_mask is not None:
        assert cache is None and not collect_state, (
            "group_mask is for the stateless pipelined forward path")

    def body(carry, inp):
        x, aux = carry
        gp, gc, gm = inp
        x_in, aux_in = x, aux
        new_gc = {}
        for j, blk in enumerate(cfg.block_pattern):
            st = gc[f"b{j}"] if gc is not None else None
            x, nst, a = apply_block(
                gp[f"b{j}"], x, cfg, blk, positions=positions, causal=causal,
                state=st, cache_index=cache_index, enc_out=enc_out,
                attend_cache=attend_cache, block_tables=block_tables,
                write_tables=write_tables)
            if nst is not None:
                new_gc[f"b{j}"] = nst
            aux = aux + a
        if gm is not None:
            live = gm > 0
            x = jnp.where(live, x, x_in)
            aux = jnp.where(live, aux, aux_in)
        out = new_gc if (collect_state and new_gc) else None
        return (x, aux), out

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    aux0 = jnp.zeros((), jnp.float32)
    (x, aux), new_cache = lax.scan(body, (x, aux0),
                                   (stack_params, cache, group_mask))
    return x, new_cache, aux
