"""Paged KV-cache bookkeeping: fixed-size block pool, per-slot block
tables, content-hash prefix sharing, copy-on-write, and LRU reuse.

This is the HOST side of the paged-cache subsystem.  The device side is a
pool of physical KV pages per attention layer
(``transformer.make_paged_cache``: ``(num_groups, num_blocks, page_size,
kv_heads, head_dim)``) addressed through a per-slot **block table** — so a
slot's KV memory is ``ceil(live_tokens / page_size)`` blocks instead of a
dense ``max_seq`` reservation, and the number of decode slots is bounded by
*live* tokens, not worst-case sequence length (the KV-memory lever both
FPGA serving studies in PAPERS.md identify as dominant).

Sharing model (vLLM-style, full-block granularity plus a partial tail):

  * every FULL block is identified by the **chain hash** of the token
    sequence from position 0 through its last token.  On admission the
    prompt's full blocks are matched against the registry longest-prefix
    first; hits are mapped into the slot's table with a refcount bump —
    the physical block is shared, its page write is skipped.
  * the first unmatched *partial* tail (prompt tokens that only fill part
    of a block) can share a registered block whose tokens *start with*
    the remaining prompt — the slot attends the shared rows under its own
    length mask.  The first decode write into such a block diverges from
    the registered content, so it **copy-on-writes**: a fresh block is
    allocated, the page is copied on device, and the table repoints.
  * registered blocks are immutable; a block is writable in place only
    while it is unregistered and referenced by exactly one slot (a slot's
    own growing tail).  Blocks register when their content is actually on
    device: prompt blocks at scatter-commit, decode blocks when the
    running token chain fills them.
  * a fully-released registered block is not freed — it parks in an LRU
    so a future prompt with the same prefix can re-admit it; the LRU is
    evicted (unregister + free) only when the pool runs dry.

Beyond memory sharing, the LRU-parked registry is a cross-request
**compute cache**: an admission whose prefix blocks hit the registry can
skip their prefill entirely (``reuse_compute=True`` reports
``AdmitPlan.reused_tokens`` — the engine prefills only the unmatched
suffix, attending the shared pages through the block table).  The
``prefill_compute_hits`` / ``reused_prefill_tokens`` counters track how
much prefill work the registry saved.

Everything here is plain numpy/python (no jax): the manager runs in the
engine's host loop and only *describes* device work (which pages the
prefill may write — ``AdmitPlan.write_table`` — and which to copy on
divergence, executed by ``transformer.copy_cache_pages``).  Prompt K/V
streams straight into the pool as the prefill runs; there is no dense
staging buffer and no commit-time copy.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_ROOT = ("kv-chain-root",)   # parent "hash" of the first block


def _chain_hash(parent, tokens) -> int:
    return hash((parent, tuple(int(t) for t in tokens)))


class PoolExhausted(RuntimeError):
    """The block pool has no free or evictable block left."""


class ConcurrentPeakTracker:
    """Concurrent peak of blocks-in-use ACROSS a set of pools.

    Per-pool ``peak_in_use`` maxima occur at different times, so summing
    them overstates the true concurrent footprint (and understates the
    effective-slots gain derived from it).  Pools attached here ping the
    tracker on every allocate/retain; the tracker records the maximum of
    the *summed instantaneous* usage instead."""

    def __init__(self):
        self.pools: List[BlockPool] = []
        self.peak = 0

    def attach(self, pool: "BlockPool"):
        # Idempotent: re-planning re-attaches the surviving pools of the
        # new replica layout to the engine-lifetime tracker; a pool that
        # is already tracked must not be appended again (it would be
        # summed twice in every subsequent ``note`` and inflate the peak).
        if pool not in self.pools:
            self.pools.append(pool)
        pool.tracker = self
        self.note()

    def note(self):
        now = sum(p.blocks_in_use for p in self.pools)
        if now > self.peak:
            self.peak = now

    def reset(self):
        self.peak = sum(p.blocks_in_use for p in self.pools)


class BlockPool:
    """Fixed-size pool of physical KV blocks with refcounts, a content
    registry (chain hash -> block) for prefix sharing, and an LRU of
    fully-released registered blocks kept warm for reuse."""

    def __init__(self, num_blocks: int, page_size: int):
        if num_blocks < 1 or page_size < 1:
            raise ValueError(f"need >= 1 block and page ({num_blocks}, "
                             f"{page_size})")
        self.num_blocks = num_blocks
        self.page_size = page_size
        self.refcount = np.zeros((num_blocks,), np.int32)
        self.free: deque = deque(range(num_blocks))
        self.registry: Dict[int, int] = {}          # chain hash -> block
        self.hash_of: Dict[int, int] = {}           # block -> chain hash
        self.parent_of: Dict[int, object] = {}      # block -> parent hash
        self.tokens_of: Dict[int, np.ndarray] = {}  # block -> its tokens
        self.children: Dict[object, List[int]] = {}  # parent -> blocks
        self.lru: "OrderedDict[int, None]" = OrderedDict()  # ref 0, registered
        self.tracker: Optional[ConcurrentPeakTracker] = None
        # stats ------------------------------------------------------------
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.cow_copies = 0
        self.evictions = 0
        self.peak_in_use = 0
        # prefill compute-cache accounting (suffix-only prefill) -----------
        self.prefill_admissions = 0
        self.prefill_compute_hits = 0     # admissions that skipped compute
        self.reused_prefill_tokens = 0    # prompt tokens NOT re-prefilled
        self.suffix_prefill_tokens = 0    # prompt tokens actually computed

    # -- capacity ----------------------------------------------------------
    @property
    def blocks_in_use(self) -> int:
        """Blocks referenced by at least one slot."""
        return int(np.sum(self.refcount > 0))

    @property
    def blocks_cached(self) -> int:
        """Fully-released registered blocks parked for prefix reuse."""
        return len(self.lru)

    @property
    def blocks_free(self) -> int:
        return len(self.free)

    def available(self, excluding=()) -> int:
        """Blocks an allocation burst can obtain right now (free + LRU-
        evictable), optionally not counting blocks about to be retained."""
        ex = sum(1 for b in excluding if b in self.lru)
        return len(self.free) + len(self.lru) - ex

    # -- alloc / refcount --------------------------------------------------
    def allocate(self) -> int:
        """A fresh exclusively-owned block (refcount 1), evicting the
        least-recently-released cached block if the free list is empty."""
        if self.free:
            blk = self.free.popleft()
        elif self.lru:
            blk, _ = self.lru.popitem(last=False)   # oldest release first
            self._unregister(blk)
            self.evictions += 1
        else:
            raise PoolExhausted(
                f"block pool exhausted ({self.num_blocks} blocks of "
                f"{self.page_size} tokens all referenced); size the pool "
                f"with num_blocks >= slots * max_seq / page_size to rule "
                f"this out, or retire requests sooner")
        self.refcount[blk] = 1
        self.peak_in_use = max(self.peak_in_use, self.blocks_in_use)
        if self.tracker is not None:
            self.tracker.note()
        return int(blk)

    def retain(self, blk: int):
        """Add a reference to a (possibly LRU-parked) registered block."""
        self.refcount[blk] += 1
        self.lru.pop(blk, None)
        self.peak_in_use = max(self.peak_in_use, self.blocks_in_use)
        if self.tracker is not None:
            self.tracker.note()

    def release(self, blk: int):
        assert self.refcount[blk] > 0, blk
        self.refcount[blk] -= 1
        if self.refcount[blk] == 0:
            if blk in self.hash_of:
                self.lru[blk] = None                # park, newest at the end
            else:
                self.free.append(blk)

    def writable(self, blk: int) -> bool:
        """In-place writes need exclusive ownership of mutable content:
        exactly one reference AND not registered (registered = immutable,
        other prompts may map it)."""
        return self.refcount[blk] == 1 and blk not in self.hash_of

    # -- registry (content-hash prefix sharing) ----------------------------
    def register(self, blk: int, parent, tokens) -> bool:
        """Publish a FULL block's content under its chain hash.  First
        writer wins: a colliding hash leaves the existing block in place
        and this one unregistered (still exclusively owned, still valid)."""
        h = _chain_hash(parent, tokens)
        if h in self.registry:
            return False
        self.registry[h] = blk
        self.hash_of[blk] = h
        self.parent_of[blk] = parent
        self.tokens_of[blk] = np.asarray(tokens, np.int32).copy()
        self.children.setdefault(parent, []).append(blk)
        return True

    def lookup_full(self, parent, tokens) -> Tuple[int, Optional[int]]:
        """(chain hash, registered block or None) for a full block.  A
        hit is confirmed against the stored tokens and parent link, so a
        chain-hash collision is a clean miss rather than silently mapping
        another request's K/V."""
        h = _chain_hash(parent, tokens)
        self.prefix_queries += 1
        blk = self.registry.get(h)
        if blk is not None and (self.parent_of[blk] != parent
                                or not np.array_equal(
                                    self.tokens_of[blk],
                                    np.asarray(tokens, np.int32))):
            blk = None
        if blk is not None:
            self.prefix_hits += 1
        return h, blk

    def lookup_partial(self, parent, tokens) -> Optional[int]:
        """A registered child of ``parent`` whose content *starts with*
        ``tokens`` (the shared-partial-tail case; the extra rows are
        masked by the sharer's length until copy-on-write)."""
        self.prefix_queries += 1
        want = np.asarray(tokens, np.int32)
        for blk in self.children.get(parent, ()):
            if np.array_equal(self.tokens_of[blk][:len(want)], want):
                self.prefix_hits += 1
                return blk
        return None

    def _unregister(self, blk: int):
        h = self.hash_of.pop(blk, None)
        if h is None:
            return
        del self.registry[h]
        parent = self.parent_of.pop(blk)
        self.tokens_of.pop(blk, None)
        kids = self.children.get(parent)
        if kids is not None:
            kids.remove(blk)
            if not kids:
                del self.children[parent]


@dataclass
class BlockTable:
    """One slot's logical-to-physical block map plus its token chain (the
    chain is what names blocks for registration and prefix matching)."""
    blocks: np.ndarray                    # (max_blocks,) int32, sentinel = -1
    chain: List[int] = field(default_factory=list)   # tokens written so far
    hashes: List[int] = field(default_factory=list)  # chain hash per full blk
    reserved: int = 0                     # growth blocks reserved, not drawn

    @property
    def n_mapped(self) -> int:
        return int(np.sum(self.blocks >= 0))


@dataclass
class AdmitPlan:
    """Device work an admission implies: which logical prompt blocks the
    prefill may write (the rest are shared and already populated), the
    gather table it attends through, and how much prefill compute the
    registry saved."""
    slot: int
    shared_blocks: Tuple[int, ...]        # physical ids mapped without write
    write_logical: np.ndarray             # (max_blocks,) padded logical idx
    write_phys: np.ndarray                # (max_blocks,) padded; pad = pool
    #                                       size (dropped by the scatter)
    n_write: int
    block_table: np.ndarray               # (max_blocks,) gather table over
    #                                       ALL mapped blocks; sentinel =
    #                                       pool size for unmapped entries
    write_table: np.ndarray               # (max_blocks,) fresh block phys id
    #                                       at its logical position; shared /
    #                                       unmapped entries carry the
    #                                       sentinel so prefill writes drop
    reused_tokens: int = 0                # prefix tokens whose prefill is
    #                                       skipped (warm compute-cache hit);
    #                                       the suffix starts here


class PagedCacheManager:
    """Block-table bookkeeping for one engine (or one decode replica).

    Slots index rows of the block-table matrix; the engine calls, in
    order: ``admit`` (map + allocate at admission), ``commit`` (after the
    prompt scatter lands — publishes the slot's full blocks for sharing),
    ``prepare_decode`` (before each decode write — allocates the next
    block at a page boundary, copy-on-writes a shared/immutable one),
    ``note_written`` (after each decode step — extends the token chain,
    registers blocks as they fill), and ``release_slot`` at retirement.
    """

    def __init__(self, slots: int, max_seq: int, page_size: int,
                 num_blocks: int, *, prefix_cache: bool = True,
                 kv_dtype: str = "fp", kv_capacity_ratio: float = 1.0):
        if max_seq % page_size:
            raise ValueError(
                f"max_seq={max_seq} must be a multiple of "
                f"page_size={page_size} (block tables tile the sequence)")
        if kv_dtype not in ("fp", "int8"):
            raise ValueError(f"kv_dtype={kv_dtype!r} must be 'fp' or 'int8'")
        self.slots = slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.blocks_per_slot = max_seq // page_size
        self.prefix_cache = prefix_cache  # False: no registry lookups, no
        #                                   registration, no LRU parking
        # storage dtype of the device pool this manager fronts ("int8":
        # quantized rows + per-row scales) and the tokens-per-byte
        # multiplier over the fp layout it buys
        # (``transformer.paged_kv_capacity_ratio``)
        self.kv_dtype = kv_dtype
        self.kv_capacity_ratio = (1.0 if kv_dtype == "fp"
                                  else float(kv_capacity_ratio))
        self.pool = BlockPool(num_blocks, page_size)
        self.tables = [BlockTable(np.full((self.blocks_per_slot,), -1,
                                          np.int32))
                       for _ in range(slots)]
        self._pending: Dict[int, List[Tuple[int, int, object,
                                            np.ndarray]]] = {}
        self._pending_map: Dict[int, np.ndarray] = {}
        self._reserved = 0                # sum of per-slot growth reserves
        self.migrations = 0               # zero-copy slot handoffs served

    # -- views -------------------------------------------------------------
    def table_matrix(self) -> np.ndarray:
        """(slots, max_blocks) int32 for the decode step; unmapped entries
        carry ``num_blocks`` (one past the pool: gathers clip to a masked
        garbage page, scatters drop)."""
        out = np.stack([t.blocks for t in self.tables])
        out[out < 0] = self.pool.num_blocks
        return out

    # -- admission ---------------------------------------------------------
    def admit(self, slot: int, prompt, max_new_tokens: int = 0, *,
              reuse_compute: bool = False) -> Optional[AdmitPlan]:
        """Map the prompt onto blocks: longest-prefix match of full blocks
        against the registry, optional partial-tail share, fresh blocks
        for the rest — plus a *reservation* for the request's worst-case
        decode growth (``max_new_tokens``), drawn down as the blocks are
        actually allocated.  Returns None (no state change) when the pool
        cannot supply prompt + growth — the engine defers the admission.
        Raises PoolExhausted when the request could NEVER fit the pool.

        ``reuse_compute=True`` additionally reports the matched prefix as
        ``AdmitPlan.reused_tokens`` so the engine prefills only the
        unmatched suffix (always at least one token: the last prompt
        position is recomputed to produce the first-token logits).  Leave
        it False for families whose prefill is not suffix-decomposable
        (see ``transformer.supports_prefix_compute_reuse``) — blocks
        still share their *memory* either way."""
        P = self.page_size
        prompt = np.asarray(prompt, np.int32)
        L = len(prompt)
        assert 0 < L <= self.max_seq, (L, self.max_seq)
        n_full, rem = divmod(L, P)
        # a deferred admission is retried every tick: snapshot the reuse
        # counters so only the attempt that actually admits counts (the
        # reported hit rate is per logical admission, not per retry)
        q0, h0 = self.pool.prefix_queries, self.pool.prefix_hits

        shared: List[int] = []
        hashes: List[int] = []
        h = _ROOT
        if self.prefix_cache:
            for j in range(n_full):
                h2, blk = self.pool.lookup_full(h, prompt[j * P:(j + 1) * P])
                if blk is None:
                    break
                shared.append(blk)
                hashes.append(h2)
                h = h2
        m = len(shared)
        tail_shared = None
        if self.prefix_cache and m == n_full and rem:
            tail_shared = self.pool.lookup_partial(h, prompt[n_full * P:])

        retained = shared + ([tail_shared] if tail_shared is not None
                             else [])
        n_new = (n_full - m) + (1 if rem and tail_shared is None else 0)
        # worst-case decode growth: blocks beyond the prompt's own up to
        # the token budget (or the slot cap), plus the copy-on-write
        # replacement a shared tail will need on its first divergent
        # write.  Reserving it up front is what lets a pool smaller than
        # the dense reservation DEFER admissions instead of raising
        # PoolExhausted mid-stream.
        total_blocks = -(-min(L + max(max_new_tokens, 0), self.max_seq)
                         // P)
        growth = (total_blocks - n_full - (1 if rem else 0)
                  + (1 if tail_shared is not None else 0))
        # feasibility counts the retained shared blocks too: they occupy
        # pool capacity the fresh allocations can never reclaim, so a
        # request whose shared + fresh footprint exceeds the pool must
        # raise (deferring would livelock the FIFO head forever)
        if len(retained) + n_new + growth > self.pool.num_blocks:
            # the raise is still "no admission happened": restore the
            # reuse counters just like the deferral path below, or a
            # never-fits request would permanently skew reuse_hit_rate
            self.pool.prefix_queries, self.pool.prefix_hits = q0, h0
            raise PoolExhausted(
                f"a {L}-token prompt with max_new_tokens="
                f"{max_new_tokens} needs {len(retained) + n_new + growth} "
                f"blocks ({len(retained)} shared + {n_new + growth} "
                f"fresh) but the pool only has {self.pool.num_blocks}; "
                f"raise num_blocks or page_size")
        if (self.pool.available(excluding=retained) - self._reserved
                < n_new + growth):
            self.pool.prefix_queries, self.pool.prefix_hits = q0, h0
            return None

        for blk in retained:
            self.pool.retain(blk)
        tb = self.tables[slot]
        assert tb.n_mapped == 0, f"slot {slot} still mapped"
        # the table row is NOT written here: a reserved slot must ride
        # decode ticks with an unmapped (sentinel) row so its stale-
        # position write drops — the mapping lands at commit(), together
        # with the prefill writes that make the fresh blocks' content real.
        mapped = np.full((self.blocks_per_slot,), -1, np.int32)
        tb.chain = [int(t) for t in prompt]
        tb.hashes = list(hashes)
        for j, blk in enumerate(shared):
            mapped[j] = blk
        write_log, write_phys = [], []
        pending: List[Tuple[int, int, object, np.ndarray]] = []
        for j in range(m, n_full):
            blk = self.pool.allocate()
            mapped[j] = blk
            write_log.append(j)
            write_phys.append(blk)
            toks = prompt[j * P:(j + 1) * P]
            h = _chain_hash(h, toks)
            tb.hashes.append(h)
            pending.append((j, blk, tb.hashes[j - 1] if j else _ROOT, toks))
        if rem:
            if tail_shared is not None:
                mapped[n_full] = tail_shared
            else:
                blk = self.pool.allocate()
                mapped[n_full] = blk
                write_log.append(n_full)
                write_phys.append(blk)
        self._pending[slot] = pending
        self._pending_map[slot] = mapped
        tb.reserved = growth
        self._reserved += growth

        # compute-cache accounting: the matched prefix's prefill is
        # skipped outright (the suffix keeps at least the last prompt
        # token — its hidden state is what produces the first logits)
        matched = m * P + (rem if tail_shared is not None else 0)
        reused = min(matched, L - 1) if reuse_compute else 0
        self.pool.prefill_admissions += 1
        if reused > 0:
            self.pool.prefill_compute_hits += 1
        self.pool.reused_prefill_tokens += reused
        self.pool.suffix_prefill_tokens += L - reused

        MB, NB = self.blocks_per_slot, self.pool.num_blocks
        logical = np.zeros((MB,), np.int32)
        phys = np.full((MB,), NB, np.int32)          # pad = dropped write
        logical[:len(write_log)] = write_log
        phys[:len(write_phys)] = write_phys
        gather = mapped.copy()
        gather[gather < 0] = NB                      # sentinel: masked page
        wtable = np.full((MB,), NB, np.int32)        # sentinel: dropped write
        for j, blk in zip(write_log, write_phys):
            wtable[j] = blk
        return AdmitPlan(slot=slot,
                         shared_blocks=tuple(shared) + (
                             (tail_shared,) if tail_shared is not None
                             else ()),
                         write_logical=logical, write_phys=phys,
                         n_write=len(write_log),
                         block_table=gather, write_table=wtable,
                         reused_tokens=int(reused))

    def commit_chunk(self, slot: int, tokens_on_device: int):
        """A prefill chunk's page writes have landed: publish every
        pending FULL block the chunk completed (its content is real on
        device now) without waiting for the whole prompt.  This is what
        makes chunked prefill feed the compute cache incrementally — a
        later admission can hit blocks of a prompt still mid-prefill.
        The table-row mapping itself stays deferred to ``commit`` (a
        reserved slot riding decode must keep an unmapped row)."""
        if not self.prefix_cache:
            return
        pending = self._pending.get(slot)
        if not pending:
            return
        keep, done = [], []
        for e in pending:
            (done if (e[0] + 1) * self.page_size <= tokens_on_device
             else keep).append(e)
        if done:
            self._pending[slot] = keep
            for _, blk, parent, toks in done:
                self.pool.register(blk, parent, toks)

    def commit(self, slot: int):
        """The prefill's page writes have all landed: map the slot's
        table row and publish its remaining freshly written FULL prompt
        blocks for prefix sharing.  (Both deferred until the pages
        actually hold the K/V — a concurrently-admitted prompt must never
        map a still-garbage block, and a reserved slot riding decode must
        keep an unmapped row so its stale-position write drops.)"""
        self.tables[slot].blocks[:] = self._pending_map.pop(slot)
        for _, blk, parent, toks in self._pending.pop(slot, ()):
            if self.prefix_cache:
                self.pool.register(blk, parent, toks)

    # -- decode ------------------------------------------------------------
    def _allocate_reserved(self, tb: BlockTable) -> int:
        """Draw a decode-growth block against the slot's admission-time
        reservation (the reservation is what guarantees this allocation
        cannot raise under the admission gate)."""
        blk = self.pool.allocate()
        if tb.reserved > 0:
            tb.reserved -= 1
            self._reserved -= 1
        return blk

    def prepare_decode(self, slot: int, pos: int
                       ) -> Optional[Tuple[int, int]]:
        """Make the block holding position ``pos`` writable before the
        decode step writes it.  Allocates at a fresh page boundary;
        copy-on-writes a shared or registered block (first divergent
        write).  Returns a ``(src, dst)`` physical pair when the engine
        must copy the page on device, else None."""
        tb = self.tables[slot]
        j = pos // self.page_size
        assert j < self.blocks_per_slot, (pos, self.max_seq)
        blk = int(tb.blocks[j])
        if blk < 0:
            tb.blocks[j] = self._allocate_reserved(tb)
            return None
        if self.pool.writable(blk):
            return None
        new = self._allocate_reserved(tb)
        self.pool.release(blk)
        tb.blocks[j] = new
        self.pool.cow_copies += 1
        return (blk, new)

    def note_written(self, slot: int, token: int, pos: int):
        """A decode step wrote ``token``'s K/V at ``pos``: extend the
        chain; when the write fills its block, register the block (its
        content is now complete and on device)."""
        tb = self.tables[slot]
        assert len(tb.chain) == pos, (len(tb.chain), pos)
        tb.chain.append(int(token))
        P = self.page_size
        if (pos + 1) % P == 0:
            j = pos // P
            parent = tb.hashes[j - 1] if j else _ROOT
            toks = np.asarray(tb.chain[j * P:(j + 1) * P], np.int32)
            tb.hashes.append(_chain_hash(parent, toks))
            blk = int(tb.blocks[j])
            if self.prefix_cache and self.pool.writable(blk):
                self.pool.register(blk, parent, toks)  # exclusively ours

    def rollback(self, slot: int, pos: int):
        """Rewind the slot to ``pos`` written tokens: truncate the chain
        (and the per-block hash spine), release blocks wholly past the
        accepted position, and return them to the slot's growth
        reservation.  This is the reject path of speculative decode —
        only ever invoked on positions the slot itself just wrote, so
        every released block is a fresh exclusively-owned decode block
        (never shared, never registered: blocks register only when FULL,
        and a full block at index < ceil(pos/P) is always kept)."""
        tb = self.tables[slot]
        P = self.page_size
        n_keep = -(-pos // P)
        for j in range(n_keep, self.blocks_per_slot):
            blk = int(tb.blocks[j])
            if blk < 0:
                continue
            assert self.pool.writable(blk), (slot, j, blk)
            self.pool.release(blk)
            tb.blocks[j] = -1
            tb.reserved += 1
            self._reserved += 1
        del tb.chain[pos:]
        del tb.hashes[pos // P:]

    # -- migration ---------------------------------------------------------
    def migrate_slot(self, src: int, dst: int):
        """Hand a slot's entire paged state to another slot row: block
        table, token chain, per-block hash spine, and growth reservation
        move wholesale.  ZERO device work and zero net refcount traffic —
        the physical pool is shared, no block moves, and the number of
        references per block is unchanged (each reference merely changes
        which table row holds it).  This is the primitive behind
        cross-replica work stealing and traffic-adaptive re-planning
        (``ServingEngine.replan``): on the paged path a request IS its
        block-table row, so migration is pure host bookkeeping.

        ``src`` must be committed (the engine never migrates a slot whose
        chunked prefill is still streaming — its mapping is pending) and
        ``dst`` must be empty."""
        if src == dst:
            return
        assert src not in self._pending and src not in self._pending_map, \
            f"slot {src} is mid-prefill (mapping pending commit)"
        assert dst not in self._pending and dst not in self._pending_map, \
            f"slot {dst} has a pending admission"
        s, d = self.tables[src], self.tables[dst]
        assert d.n_mapped == 0 and not d.chain and d.reserved == 0, \
            f"destination slot {dst} is not empty"
        d.blocks[:] = s.blocks
        d.chain, d.hashes, d.reserved = s.chain, s.hashes, s.reserved
        s.blocks[:] = -1
        s.chain, s.hashes, s.reserved = [], [], 0
        self.migrations += 1

    # -- retirement --------------------------------------------------------
    def release_slot(self, slot: int):
        tb = self.tables[slot]
        # an uncommitted admission keeps its mapping in _pending_map (the
        # table row stays sentinel until commit) — release whichever holds
        # the slot's references
        mapped = self._pending_map.pop(slot, tb.blocks)
        for blk in mapped:
            if blk >= 0:
                self.pool.release(int(blk))
        tb.blocks[:] = -1
        tb.chain = []
        tb.hashes = []
        self._reserved -= tb.reserved     # unused growth returns to the pool
        tb.reserved = 0
        self._pending.pop(slot, None)

    # -- stats -------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        p = self.pool
        return {
            "page_size": self.page_size,
            "num_blocks": p.num_blocks,
            "blocks_in_use": p.blocks_in_use,
            "blocks_cached": p.blocks_cached,
            "blocks_free": p.blocks_free,
            "peak_blocks_in_use": p.peak_in_use,
            "prefix_queries": p.prefix_queries,
            "prefix_hits": p.prefix_hits,
            "reuse_hit_rate": p.prefix_hits / max(p.prefix_queries, 1),
            "cow_copies": p.cow_copies,
            "evictions": p.evictions,
            "migrations": self.migrations,
            "prefix_cache": self.prefix_cache,
            "prefill_admissions": p.prefill_admissions,
            "prefill_compute_hits": p.prefill_compute_hits,
            "prefill_hit_rate": (p.prefill_compute_hits
                                 / max(p.prefill_admissions, 1)),
            "reused_prefill_tokens": p.reused_prefill_tokens,
            "suffix_prefill_tokens": p.suffix_prefill_tokens,
            "kv_dtype": self.kv_dtype,
            "kv_capacity_x": self.kv_capacity_ratio,
        }
