"""Paged KV-cache subsystem: block pool + per-slot block tables + content-
hash prefix sharing for the serving engine (see ``repro.cache.paged``)."""
from repro.cache.paged import (AdmitPlan, BlockPool, BlockTable,
                               ConcurrentPeakTracker, PagedCacheManager,
                               PoolExhausted)

__all__ = ["AdmitPlan", "BlockPool", "BlockTable", "ConcurrentPeakTracker",
           "PagedCacheManager", "PoolExhausted"]
