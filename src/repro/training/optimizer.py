"""AdamW in pure JAX (no optax dependency), with cosine schedule, global
gradient clipping, and ZeRO-1-style optimizer-state sharding hooks.

Moments are stored fp32; `zero1_specs` extends each parameter's
PartitionSpec with the data axis on the first still-unsharded divisible dim
so the m/v buffers spread over the whole pod (ZeRO-1)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1

    def schedule(self, step):
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - self.warmup_steps)
                        / max(self.total_steps - self.warmup_steps, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        frac = self.min_lr_frac + (1 - self.min_lr_frac) * cos
        return self.lr * warm * frac

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        # global-norm clip
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9))
        lr = self.schedule(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mh = m / b1c
            vh = v / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x:
                             isinstance(x, tuple) and len(x) == 3)
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x:
                             isinstance(x, tuple) and len(x) == 3)
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x:
                             isinstance(x, tuple) and len(x) == 3)
        return new_p, AdamWState(step=step, m=new_m, v=new_v), \
            {"grad_norm": gn, "lr": lr}


def zero1_specs(param_specs, params, mesh) -> Any:
    """Optimizer-moment specs: parameter spec + 'data' sharding on the first
    dim that is unsharded and divisible by the data axis (ZeRO-1)."""
    if "data" not in mesh.axis_names:
        return param_specs
    dsz = mesh.shape["data"]

    def f(spec: P, p):
        entries = list(spec) + [None] * (p.ndim - len(spec))
        used = {n for e in entries if e is not None
                for n in (e if isinstance(e, tuple) else (e,))}
        if "data" in used:          # FSDP already spreads over data
            return P(*entries)
        for i, (e, dim) in enumerate(zip(entries, p.shape)):
            if e is None and dim % dsz == 0 and dim >= dsz:
                entries[i] = "data"
                break
        return P(*entries)
    return jax.tree.map(f, param_specs, params,
                        is_leaf=lambda x: isinstance(x, P))
