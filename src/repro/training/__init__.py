from repro.training.optimizer import AdamW, AdamWState, zero1_specs
from repro.training.trainer import jit_train_step, make_train_step
