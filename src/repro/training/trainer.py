"""Train-step factory: loss -> grad -> AdamW, with remat, gradient
accumulation (microbatching), donation, and sharding constraints — the
function handed to jit/pjit and to the dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.optimizer import AdamW, AdamWState


def make_train_step(model: Model, opt: AdamW, *, remat: bool = True,
                    grad_accum: int = 1) -> Callable:
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics).  With grad_accum > 1 the global batch is
    split on the leading axis and gradients are accumulated in a scan
    (microbatching — keeps peak activation memory 1/grad_accum)."""

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=remat)

    def split_mb(batch):
        def f(x):
            if x.ndim == 0:
                return x
            # positions: (3, B, S) — microbatch on axis 1
            if x.shape[0] == 3 and x.ndim == 3:
                b = x.shape[1]
                return jnp.moveaxis(
                    x.reshape(x.shape[0], grad_accum, b // grad_accum,
                              x.shape[2]), 1, 0)
            b = x.shape[0]
            return x.reshape((grad_accum, b // grad_accum) + x.shape[1:])
        return jax.tree.map(f, batch)

    def train_step(params, opt_state: AdamWState, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = split_mb(batch)

            def body(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (loss_acc + l, g_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), g0), mbs)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        params, opt_state, om = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def jit_train_step(train_step, mesh, param_shardings, opt_shardings,
                   batch_shardings):
    """pjit wrapper with donation of params/opt_state."""
    return jax.jit(
        train_step,
        in_shardings=(param_shardings, opt_shardings, batch_shardings),
        out_shardings=(param_shardings, opt_shardings, None),
        donate_argnums=(0, 1),
    )
