"""Kernel-dispatch front door: one routing decision for every hot kernel.

Each ``dispatch_*`` picks the execution path for its kernel:

  * ``pallas``    — compiled Pallas-TPU (the fused production path);
  * ``interpret`` — the same Pallas kernel under the interpreter (CPU
                    correctness testing of the real kernel body);
  * ``ref``       — the pure-jnp oracle in ``repro.kernels.ref`` (XLA-fused
                    on any backend; the CPU serving/training path).

Selection: ``REPRO_KERNELS`` env var forces a path ("pallas" /
"interpret" / "ref"); the default "auto" resolves to ``pallas`` on TPU and
``ref`` elsewhere.  Models, the serving engine, and the pipeline executor
all call through here, so one flag flips the whole system between the
fused kernels and the oracle — and the kernel sweep tests compare the two.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.backend import compat

_PATHS = ("pallas", "interpret", "ref")


def kernel_path() -> str:
    """The active kernel path ("pallas" | "interpret" | "ref").

    ``REPRO_KERNELS`` must be one of "auto" / "pallas" / "interpret" /
    "ref"; anything else raises (a typo silently falling back to the jnp
    oracle would fake a kernel benchmark)."""
    mode = os.environ.get("REPRO_KERNELS", "auto")
    if mode == "auto":
        return "pallas" if compat.on_tpu() else "ref"
    if mode not in _PATHS:
        raise ValueError(
            f"REPRO_KERNELS={mode!r} is not a valid kernel path; choose "
            f"one of {('auto',) + _PATHS}")
    return mode


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def use_flash(cfg, q, k) -> bool:
    """Whether the model's attention should route to the fused kernel:
    only when shapes tile cleanly to the MXU and we're not on the oracle
    path.  (The jnp fallback is itself XLA-fused on CPU.)"""
    if kernel_path() == "ref":
        return False
    b, s, h, d = q.shape
    t = k.shape[1]
    return (s % 8 == 0 and t % 128 == 0 and d % 128 == 0)


def dispatch_flash_attention(q, k, v, *, q_pos, k_pos, k_valid=None,
                             causal=True, window=0, softcap=0.0):
    """Layout adapter: (B,S,H,D) model layout -> (B,H,S,D) kernel layout;
    returns (B, S, H*D)."""
    from repro.kernels import ref as R
    qk = jnp.swapaxes(q, 1, 2)
    kk = jnp.swapaxes(k, 1, 2)
    vk = jnp.swapaxes(v, 1, 2)
    if k_valid is None:
        k_valid = jnp.ones((kk.shape[2],), jnp.int32)
    path = kernel_path()
    if path == "ref":
        out = R.flash_attention_ref(qk, kk, vk, q_pos, k_pos, k_valid,
                                    causal=causal, window=window,
                                    softcap=softcap)
    else:
        from repro.kernels.flash_attention import flash_attention_bhsd
        out = flash_attention_bhsd(qk, kk, vk, q_pos, k_pos, k_valid,
                                   causal=causal, window=window,
                                   softcap=softcap,
                                   interpret=(path == "interpret"))
    return jnp.swapaxes(out, 1, 2).reshape(q.shape[0], q.shape[1], -1)


# ---------------------------------------------------------------------------
# paged attention (decode over a block-pool KV cache)
# ---------------------------------------------------------------------------

def dispatch_paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                             softcap=0.0):
    """Decode attention through per-slot block tables over a physical
    page pool.  Layout adapter: q arrives in model layout (B, 1, H, D)
    and leaves as (B, 1, H*D); pages are (N, P, Hkv, D); block_tables
    (B, NB) int32 may carry out-of-range entries for unmapped logical
    blocks (clipped here — rows past ``lengths`` are masked regardless);
    lengths (B,) counts each slot's valid tokens.

    The pallas path additionally requires MXU-friendly tiling (head_dim
    % 128, page % 8); off-tile shapes fall back to the jnp reference,
    which the parity tests pin the kernel against."""
    from repro.kernels import ref as R
    b, s, h, d = q.shape
    assert s == 1, f"paged attention is a decode (one-token) path, got {s}"
    hk = k_pages.shape[2]
    qg = q[:, 0].reshape(b, hk, h // hk, d)
    n = k_pages.shape[0]
    bt = jnp.clip(block_tables, 0, n - 1)
    path = kernel_path()
    if path == "ref" or (path == "pallas"
                         and not (d % 128 == 0
                                  and k_pages.shape[1] % 8 == 0)):
        out = R.paged_attention_ref(qg, k_pages, v_pages, bt, lengths,
                                    softcap=softcap)
    else:
        from repro.kernels.paged_attention import paged_attention_grouped
        out = paged_attention_grouped(qg, k_pages, v_pages, bt, lengths,
                                      softcap=softcap,
                                      interpret=(path == "interpret"))
    return out.reshape(b, s, h * d)


def dispatch_paged_prefill_attention(q, k_pages, v_pages, block_tables,
                                     offset, *, softcap=0.0):
    """Suffix/chunked prefill attention through per-slot block tables:
    the fresh chunk's K/V are already written into the pool, and every
    query attends the full mapped prefix (shared + fresh) under a causal
    mask.  Layout adapter: q arrives in model layout (B, S, H, D) and
    leaves as (B, S, H*D); pages are (N, P, Hkv, D); block_tables (B, NB)
    int32 may carry out-of-range entries for unmapped logical blocks
    (clipped here, causally masked); offset () int32 is the position of
    the first fresh query.

    The pallas path additionally requires MXU-friendly tiling (head_dim
    % 128, page % 8, G*S % 8); off-tile shapes fall back to the jnp
    reference, which the kernel sweep tests pin the kernel against."""
    from repro.kernels import ref as R
    b, s, h, d = q.shape
    hk = k_pages.shape[2]
    g = h // hk
    qg = jnp.swapaxes(q, 1, 2).reshape(b, hk, g, s, d)
    n = k_pages.shape[0]
    bt = jnp.clip(block_tables, 0, n - 1)
    path = kernel_path()
    if path == "ref" or (path == "pallas"
                         and not (d % 128 == 0
                                  and k_pages.shape[1] % 8 == 0
                                  and (g * s) % 8 == 0)):
        out = R.paged_prefill_attention_ref(qg, k_pages, v_pages, bt,
                                            offset, softcap=softcap)
    else:
        from repro.kernels.paged_attention import (
            paged_prefill_attention_grouped)
        out = paged_prefill_attention_grouped(
            qg, k_pages, v_pages, bt, offset, softcap=softcap,
            interpret=(path == "interpret"))
    return jnp.swapaxes(out.reshape(b, hk * g, s, d), 1, 2).reshape(
        b, s, h * d)


def dispatch_paged_verify_attention(q, k_pages, v_pages, block_tables,
                                    offset, *, softcap=0.0):
    """Speculative-verify attention through per-slot block tables: each
    slot's S-token verify window (current token + drafted tokens) is
    already written into the pool and attends its full mapped prefix
    under a causal mask anchored at a PER-SLOT ``offset`` (B,) — slots
    verify at different sequence depths in one batched step.  Layout
    adapter: q arrives in model layout (B, S, H, D) and leaves as
    (B, S, H*D).

    Verify windows are short (K+1 tokens) and off the steady-state
    decode hot loop, so every path runs the jnp reference for now; a
    Pallas lowering can slot in behind this front door without touching
    callers."""
    from repro.kernels import ref as R
    b, s, h, d = q.shape
    hk = k_pages.shape[2]
    g = h // hk
    qg = jnp.swapaxes(q, 1, 2).reshape(b, hk, g, s, d)
    n = k_pages.shape[0]
    bt = jnp.clip(block_tables, 0, n - 1)
    out = R.paged_verify_attention_ref(qg, k_pages, v_pages, bt, offset,
                                       softcap=softcap)
    return jnp.swapaxes(out.reshape(b, hk * g, s, d), 1, 2).reshape(
        b, s, h * d)


# ---------------------------------------------------------------------------
# fused matmul
# ---------------------------------------------------------------------------

def dispatch_matmul(x, w, bias=None, *, activation="none", out_dtype=None):
    from repro.kernels import ref as R
    path = kernel_path()
    if path == "ref":
        return R.matmul_fused_ref(x, w, bias, activation=activation,
                                  out_dtype=out_dtype)
    from repro.kernels.fused_matmul import matmul_fused
    return matmul_fused(x, w, bias, activation=activation,
                        out_dtype=out_dtype,
                        interpret=(path == "interpret"))


# ---------------------------------------------------------------------------
# one-pass norm
# ---------------------------------------------------------------------------

def dispatch_layernorm(x, scale, bias=None, *, kind="rmsnorm", eps=1e-6):
    from repro.kernels import ref as R
    path = kernel_path()
    if path == "ref":
        return R.norm_onepass_ref(x, scale, bias, kind=kind, eps=eps)
    from repro.kernels.layernorm import norm_onepass
    return norm_onepass(x, scale, bias, kind=kind, eps=eps,
                        interpret=(path == "interpret"))


# ---------------------------------------------------------------------------
# linear recurrence scan
# ---------------------------------------------------------------------------

def use_scan_kernel() -> bool:
    """Whether recurrent models should flatten into the Pallas linear-scan
    kernel (vs the model-side chunked associative scan on the ref path)."""
    return kernel_path() != "ref"


def dispatch_linear_scan(a, b, h0=None):
    """a, b: (N, S, F).  Returns all states (N, S, F)."""
    from repro.kernels import ref as R
    path = kernel_path()
    if path == "ref":
        return R.linear_scan_ref(a, b, h0)
    from repro.kernels.linear_scan import linear_scan
    return linear_scan(a, b, h0, interpret=(path == "interpret"))


__all__ = [
    "kernel_path", "use_flash", "use_scan_kernel",
    "dispatch_flash_attention", "dispatch_paged_attention",
    "dispatch_paged_prefill_attention", "dispatch_paged_verify_attention",
    "dispatch_matmul", "dispatch_layernorm", "dispatch_linear_scan",
]
