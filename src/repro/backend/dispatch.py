"""Kernel-dispatch front door: one routing decision for every hot kernel.

Each ``dispatch_*`` picks the execution path for its kernel:

  * ``pallas``    — compiled Pallas-TPU (the fused production path);
  * ``interpret`` — the same Pallas kernel under the interpreter (CPU
                    correctness testing of the real kernel body);
  * ``ref``       — the pure-jnp oracle in ``repro.kernels.ref`` (XLA-fused
                    on any backend; the CPU serving/training path).

Selection: ``REPRO_KERNELS`` env var forces a path ("pallas" /
"interpret" / "ref"); the default "auto" resolves to ``pallas`` on TPU and
``ref`` elsewhere.  Models, the serving engine, and the pipeline executor
all call through here, so one flag flips the whole system between the
fused kernels and the oracle — and the kernel sweep tests compare the two.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.backend import compat

_PATHS = ("pallas", "interpret", "ref")

# every dispatchable kernel, by override name (REPRO_KERNELS=name=path)
_KERNELS = ("matmul", "flash_attention", "paged_attention",
            "paged_prefill_attention", "paged_verify_attention",
            "fused_paged_decode", "layernorm", "linear_scan")


def _resolve(path: str) -> str:
    if path == "auto":
        return "pallas" if compat.on_tpu() else "ref"
    return path


def kernel_path(kernel: str = None) -> str:
    """The active path ("pallas" | "interpret" | "ref") for ``kernel``
    (or the global default when ``kernel`` is None).

    ``REPRO_KERNELS`` is a comma-separated list: one bare base path
    ("auto" / "pallas" / "interpret" / "ref") plus optional per-kernel
    overrides ``name=path`` (e.g. "ref,fused_paged_decode=interpret" runs
    everything on the oracle but the fused decode kernel under the
    interpreter).  Anything else raises (a typo silently falling back to
    the jnp oracle would fake a kernel benchmark)."""
    mode = os.environ.get("REPRO_KERNELS", "auto")
    base, overrides = "auto", {}
    for part in mode.split(","):
        part = part.strip()
        name, sep, val = part.partition("=")
        if sep:
            if name not in _KERNELS or val not in ("auto",) + _PATHS:
                raise ValueError(
                    f"REPRO_KERNELS override {part!r} is not valid; paths "
                    f"are {('auto',) + _PATHS} and kernel names are "
                    f"{_KERNELS}")
            overrides[name] = val
        else:
            if part not in ("auto",) + _PATHS:
                raise ValueError(
                    f"REPRO_KERNELS={part!r} is not a valid kernel path; "
                    f"choose one of {('auto',) + _PATHS}, or a per-kernel "
                    f"override 'name=path' with name in {_KERNELS}")
            base = part
    return _resolve(overrides.get(kernel, base))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def use_flash(cfg, q, k) -> bool:
    """Whether the model's attention should route to the fused kernel:
    only when shapes tile cleanly to the MXU and we're not on the oracle
    path.  (The jnp fallback is itself XLA-fused on CPU.)"""
    if kernel_path("flash_attention") == "ref":
        return False
    b, s, h, d = q.shape
    t = k.shape[1]
    return (s % 8 == 0 and t % 128 == 0 and d % 128 == 0)


def dispatch_flash_attention(q, k, v, *, q_pos, k_pos, k_valid=None,
                             causal=True, window=0, softcap=0.0):
    """Layout adapter: (B,S,H,D) model layout -> (B,H,S,D) kernel layout;
    returns (B, S, H*D)."""
    from repro.kernels import ref as R
    qk = jnp.swapaxes(q, 1, 2)
    kk = jnp.swapaxes(k, 1, 2)
    vk = jnp.swapaxes(v, 1, 2)
    if k_valid is None:
        k_valid = jnp.ones((kk.shape[2],), jnp.int32)
    path = kernel_path("flash_attention")
    if path == "ref":
        out = R.flash_attention_ref(qk, kk, vk, q_pos, k_pos, k_valid,
                                    causal=causal, window=window,
                                    softcap=softcap)
    else:
        from repro.kernels.flash_attention import flash_attention_bhsd
        out = flash_attention_bhsd(qk, kk, vk, q_pos, k_pos, k_valid,
                                   causal=causal, window=window,
                                   softcap=softcap,
                                   interpret=(path == "interpret"))
    return jnp.swapaxes(out, 1, 2).reshape(q.shape[0], q.shape[1], -1)


# ---------------------------------------------------------------------------
# paged attention (decode over a block-pool KV cache)
# ---------------------------------------------------------------------------

def dispatch_paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                             softcap=0.0, k_scales=None, v_scales=None):
    """Decode attention through per-slot block tables over a physical
    page pool.  Layout adapter: q arrives in model layout (B, 1, H, D)
    and leaves as (B, 1, H*D); pages are (N, P, Hkv, D); block_tables
    (B, NB) int32 may carry out-of-range entries for unmapped logical
    blocks (clipped here — rows past ``lengths`` are masked regardless);
    lengths (B,) counts each slot's valid tokens.  k_scales/v_scales:
    (N, P, Hkv) f32 dequant scales on int8 pools (None on fp).

    The pallas path additionally requires MXU-friendly tiling (head_dim
    % 128, page % 8); off-tile shapes fall back to the jnp reference,
    which the parity tests pin the kernel against."""
    from repro.kernels import ref as R
    b, s, h, d = q.shape
    assert s == 1, f"paged attention is a decode (one-token) path, got {s}"
    hk = k_pages.shape[2]
    qg = q[:, 0].reshape(b, hk, h // hk, d)
    n = k_pages.shape[0]
    bt = jnp.clip(block_tables, 0, n - 1)
    path = kernel_path("paged_attention")
    if path == "ref" or k_scales is not None \
            or (path == "pallas" and not (d % 128 == 0
                                          and k_pages.shape[1] % 8 == 0)):
        out = R.paged_attention_ref(qg, k_pages, v_pages, bt, lengths,
                                    softcap=softcap, k_scales=k_scales,
                                    v_scales=v_scales)
    else:
        from repro.kernels.paged_attention import paged_attention_grouped
        out = paged_attention_grouped(qg, k_pages, v_pages, bt, lengths,
                                      softcap=softcap,
                                      interpret=(path == "interpret"))
    return out.reshape(b, s, h * d)


def dispatch_fused_paged_decode(q, k_new, v_new, k_pages, v_pages,
                                block_tables, positions, *, theta,
                                softcap=0.0, k_scales=None, v_scales=None):
    """Fused RoPE + page-write + decode attention: one kernel, one HBM
    round-trip over the pool instead of three (rope-write / gather /
    attend).  Layout adapter: q arrives UN-roped in model layout
    (B, 1, H, D), k_new/v_new as the slot's un-roped fresh projections
    (B, 1, Hkv, D); positions (B,) is each slot's write position (tokens
    already cached — the length mask runs at positions+1).  Returns
    ``(out (B, 1, H*D), k_pages, v_pages, k_scales, v_scales)`` — the
    pool buffers with the fresh (quantized, on int8 pools) row written.

    The pallas path requires MXU-friendly tiling (head_dim % 128,
    page % 8; int8 pools additionally page % 32 for the (32, 128) int8
    tile); off-tile shapes fall back to the jnp reference, which the
    parity tests pin the kernel against."""
    from repro.kernels import ref as R
    b, s, h, d = q.shape
    assert s == 1, f"fused paged decode is a one-token path, got {s}"
    hk = k_pages.shape[2]
    qg = q[:, 0].reshape(b, hk, h // hk, d)
    kn = k_new[:, 0]
    vn = v_new[:, 0]
    n = k_pages.shape[0]
    bt = jnp.clip(block_tables, 0, n - 1)
    page = k_pages.shape[1]
    path = kernel_path("fused_paged_decode")
    tiled = d % 128 == 0 and page % 8 == 0 \
        and (k_scales is None or page % 32 == 0)
    if path == "ref" or (path == "pallas" and not tiled):
        out, kp, vp, ks, vs = R.fused_paged_decode_ref(
            qg, kn, vn, k_pages, v_pages, bt, positions, theta=theta,
            softcap=softcap, k_scales=k_scales, v_scales=v_scales)
    else:
        from repro.kernels.paged_attention import fused_paged_decode_grouped
        out, kp, vp, ks, vs = fused_paged_decode_grouped(
            qg, kn, vn, k_pages, v_pages, bt, positions, theta=theta,
            softcap=softcap, k_scales=k_scales, v_scales=v_scales,
            interpret=(path == "interpret"))
    return out.reshape(b, s, h * d), kp, vp, ks, vs


def dispatch_paged_prefill_attention(q, k_pages, v_pages, block_tables,
                                     offset, *, softcap=0.0, k_scales=None,
                                     v_scales=None):
    """Suffix/chunked prefill attention through per-slot block tables:
    the fresh chunk's K/V are already written into the pool, and every
    query attends the full mapped prefix (shared + fresh) under a causal
    mask.  Layout adapter: q arrives in model layout (B, S, H, D) and
    leaves as (B, S, H*D); pages are (N, P, Hkv, D); block_tables (B, NB)
    int32 may carry out-of-range entries for unmapped logical blocks
    (clipped here, causally masked); offset () int32 is the position of
    the first fresh query.

    The pallas path additionally requires MXU-friendly tiling (head_dim
    % 128, page % 8, G*S % 8); off-tile shapes fall back to the jnp
    reference, which the kernel sweep tests pin the kernel against."""
    from repro.kernels import ref as R
    b, s, h, d = q.shape
    hk = k_pages.shape[2]
    g = h // hk
    qg = jnp.swapaxes(q, 1, 2).reshape(b, hk, g, s, d)
    n = k_pages.shape[0]
    bt = jnp.clip(block_tables, 0, n - 1)
    path = kernel_path("paged_prefill_attention")
    if path == "ref" or k_scales is not None \
            or (path == "pallas" and not (d % 128 == 0
                                          and k_pages.shape[1] % 8 == 0
                                          and (g * s) % 8 == 0)):
        out = R.paged_prefill_attention_ref(qg, k_pages, v_pages, bt,
                                            offset, softcap=softcap,
                                            k_scales=k_scales,
                                            v_scales=v_scales)
    else:
        from repro.kernels.paged_attention import (
            paged_prefill_attention_grouped)
        out = paged_prefill_attention_grouped(
            qg, k_pages, v_pages, bt, offset, softcap=softcap,
            interpret=(path == "interpret"))
    return jnp.swapaxes(out.reshape(b, hk * g, s, d), 1, 2).reshape(
        b, s, h * d)


def dispatch_paged_verify_attention(q, k_pages, v_pages, block_tables,
                                    offset, *, softcap=0.0, k_scales=None,
                                    v_scales=None):
    """Speculative-verify attention through per-slot block tables: each
    slot's S-token verify window (current token + drafted tokens) is
    already written into the pool and attends its full mapped prefix
    under a causal mask anchored at a PER-SLOT ``offset`` (B,) — slots
    verify at different sequence depths in one batched step.  Layout
    adapter: q arrives in model layout (B, S, H, D) and leaves as
    (B, S, H*D).

    Verify windows are short (K+1 tokens) and off the steady-state
    decode hot loop, so every path runs the jnp reference for now; a
    Pallas lowering can slot in behind this front door without touching
    callers."""
    from repro.kernels import ref as R
    b, s, h, d = q.shape
    hk = k_pages.shape[2]
    g = h // hk
    qg = jnp.swapaxes(q, 1, 2).reshape(b, hk, g, s, d)
    n = k_pages.shape[0]
    bt = jnp.clip(block_tables, 0, n - 1)
    out = R.paged_verify_attention_ref(qg, k_pages, v_pages, bt, offset,
                                       softcap=softcap, k_scales=k_scales,
                                       v_scales=v_scales)
    return jnp.swapaxes(out.reshape(b, hk * g, s, d), 1, 2).reshape(
        b, s, h * d)


# ---------------------------------------------------------------------------
# fused matmul
# ---------------------------------------------------------------------------

def dispatch_matmul(x, w, bias=None, *, activation="none", out_dtype=None):
    from repro.kernels import ref as R
    path = kernel_path("matmul")
    if path == "ref":
        return R.matmul_fused_ref(x, w, bias, activation=activation,
                                  out_dtype=out_dtype)
    from repro.kernels.fused_matmul import matmul_fused
    return matmul_fused(x, w, bias, activation=activation,
                        out_dtype=out_dtype,
                        interpret=(path == "interpret"))


# ---------------------------------------------------------------------------
# one-pass norm
# ---------------------------------------------------------------------------

def dispatch_layernorm(x, scale, bias=None, *, kind="rmsnorm", eps=1e-6):
    from repro.kernels import ref as R
    path = kernel_path("layernorm")
    if path == "ref":
        return R.norm_onepass_ref(x, scale, bias, kind=kind, eps=eps)
    from repro.kernels.layernorm import norm_onepass
    return norm_onepass(x, scale, bias, kind=kind, eps=eps,
                        interpret=(path == "interpret"))


# ---------------------------------------------------------------------------
# linear recurrence scan
# ---------------------------------------------------------------------------

def use_scan_kernel() -> bool:
    """Whether recurrent models should flatten into the Pallas linear-scan
    kernel (vs the model-side chunked associative scan on the ref path)."""
    return kernel_path("linear_scan") != "ref"


def dispatch_linear_scan(a, b, h0=None):
    """a, b: (N, S, F).  Returns all states (N, S, F)."""
    from repro.kernels import ref as R
    path = kernel_path("linear_scan")
    if path == "ref":
        return R.linear_scan_ref(a, b, h0)
    from repro.kernels.linear_scan import linear_scan
    return linear_scan(a, b, h0, interpret=(path == "interpret"))


__all__ = [
    "kernel_path", "use_flash", "use_scan_kernel",
    "dispatch_flash_attention", "dispatch_paged_attention",
    "dispatch_fused_paged_decode",
    "dispatch_paged_prefill_attention", "dispatch_paged_verify_attention",
    "dispatch_matmul", "dispatch_layernorm", "dispatch_linear_scan",
]
