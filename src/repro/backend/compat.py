"""jax version-portability layer (supported range: 0.4.37 — 0.7.x).

Every jax API whose surface moved between 0.4.x and 0.7.x is shimmed HERE
and nowhere else: the rest of the repo imports these helpers and never
probes ``jax.__version__``, ``hasattr(jax, ...)``, or constructs
``pltpu.*`` objects directly.  This is what lets the SSR latency-throughput
explorer retarget backends (the paper's §6 portability argument): the
execution layer is decoupled from any single jax vintage, so the same code
is green on CPU-only jax 0.4.37 and lights up unchanged on TPU 0.7.x.

Shimmed surfaces:
  * ``pltpu.TPUCompilerParams`` (0.4.x)  vs  ``pltpu.CompilerParams`` (0.7)
  * Pallas TPU memory spaces (``VMEM``/``SMEM``/``ANY`` scratch shapes)
  * ``jax.sharding.AxisType`` + ``jax.make_mesh(..., axis_types=...)``
    (absent on 0.4.x — plain ``jax.make_mesh``/``mesh_utils`` fallback)
  * ``jax.set_mesh`` / ``jax.sharding.use_mesh``  vs  ``with mesh:``
  * ``AbstractMesh(sizes, names)``  vs  ``AbstractMesh(((name, size), ...))``
  * ``jax.shard_map(..., axis_names=...)`` (partial-manual)  vs
    ``jax.experimental.shard_map.shard_map`` (full-manual fallback: 0.4.x
    partial-auto is NotImplemented eagerly and miscompiles under SPMD)
  * ``lax.pcast(..., to="varying")`` (no-op before the varying-axes rework)
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec

SUPPORTED_RANGE = ("0.4.37", "0.7")


def jax_version() -> Tuple[int, ...]:
    """Parsed jax version, numeric components only (e.g. (0, 4, 37)).
    A pre-release/dev suffix contributes its leading digits then ends the
    parse: "0.7.0rc1" -> (0, 7, 0), "0.4.38.dev2025" -> (0, 4, 38)."""
    import re
    parts = []
    for p in jax.__version__.split("."):
        m = re.match(r"\d+", p)
        if not m:
            break
        parts.append(int(m.group()))
        if m.end() != len(p):
            break
    return tuple(parts)


# ---------------------------------------------------------------------------
# backend identity
# ---------------------------------------------------------------------------

def backend() -> str:
    """The default jax backend platform name ("cpu" / "tpu" / "gpu")."""
    return jax.default_backend()


def on_tpu() -> bool:
    return backend() == "tpu"


# ---------------------------------------------------------------------------
# Pallas TPU symbols
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _pltpu():
    from jax.experimental.pallas import tpu as pltpu_mod
    return pltpu_mod


def tpu_compiler_params(*, dimension_semantics=None, **kwargs):
    """``pltpu.CompilerParams`` (jax>=0.7) or ``pltpu.TPUCompilerParams``
    (jax 0.4.x–0.6.x) — whichever the installed jax provides."""
    mod = _pltpu()
    cls = getattr(mod, "CompilerParams", None) or mod.TPUCompilerParams
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = tuple(dimension_semantics)
    return cls(**kwargs)


def vmem_scratch(shape, dtype):
    """VMEM scratch allocation for ``pallas_call(scratch_shapes=...)``."""
    return _pltpu().VMEM(tuple(shape), dtype)


def smem_scratch(shape, dtype):
    """SMEM scratch allocation for ``pallas_call(scratch_shapes=...)``."""
    return _pltpu().SMEM(tuple(shape), dtype)


def prefetch_grid_spec(*, num_scalar_prefetch, grid, in_specs, out_specs,
                       scratch_shapes=()):
    """``pltpu.PrefetchScalarGridSpec`` — scalar-prefetch grid spec whose
    index maps can read int32 operands (e.g. block tables) before the
    kernel body runs.  Stable across the supported jax range; shimmed here
    so only ``repro.backend`` touches the pltpu namespace."""
    return _pltpu().PrefetchScalarGridSpec(
        num_scalar_prefetch=num_scalar_prefetch, grid=tuple(grid),
        in_specs=list(in_specs), out_specs=out_specs,
        scratch_shapes=list(scratch_shapes))


# ---------------------------------------------------------------------------
# meshes
# ---------------------------------------------------------------------------

def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """Device mesh with explicit-Auto axis types where the API exists
    (jax>=0.7 GSPMD propagation + constraints), plain mesh elsewhere."""
    shape = tuple(shape)
    axes = tuple(axes)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None and hasattr(jax, "make_mesh"):
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:  # make_mesh without axis_types kwarg
            pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils  # pragma: no cover (jax<0.4.35)
    devices = mesh_utils.create_device_mesh(shape)
    return Mesh(devices, axes)


def make_mesh_on(devices, axes) -> Mesh:
    """Mesh over an explicit device array (subset meshes — e.g. the
    plan-mesh's ``S * width`` devices out of a larger pod), with the same
    explicit-Auto axis types ``make_mesh`` applies where the API exists."""
    arr = np.asarray(devices)
    axes = tuple(axes)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return Mesh(arr, axes, axis_types=(axis_type.Auto,) * arr.ndim)
        except TypeError:  # Mesh without axis_types kwarg
            pass
    return Mesh(arr, axes)


def use_mesh(mesh):
    """Context manager placing ``mesh`` in ambient context.

    jax>=0.7: ``jax.set_mesh``; 0.5.x–0.6.x: ``jax.sharding.use_mesh``;
    0.4.x: the classic ``with mesh:`` context (Mesh is its own manager)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)

    @contextlib.contextmanager
    def _ctx():
        with mesh:
            yield mesh
    return _ctx()


def make_abstract_mesh(shape: Sequence[int], names: Sequence[str]):
    """``jax.sharding.AbstractMesh`` across the constructor split:
    jax>=0.5 takes ``(sizes, names)``; 0.4.x takes a single
    ``((name, size), ...)`` shape tuple."""
    from jax.sharding import AbstractMesh
    shape = tuple(shape)
    names = tuple(names)
    try:
        return AbstractMesh(shape, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, shape)))


def mesh_axis_size(mesh, name) -> int:
    """Product of the sizes of ``name`` (str or sequence of str) on any
    mesh flavour (Mesh / AbstractMesh) — 1 for absent axes."""
    names = (name,) if isinstance(name, str) else tuple(name)
    sizes = [mesh.shape[n] for n in names if n in mesh.shape]
    return int(np.prod(sizes)) if sizes else 1


# ---------------------------------------------------------------------------
# shard_map / varying-axes
# ---------------------------------------------------------------------------

def shard_map(f, *, mesh, in_specs, out_specs,
              manual_axes: Optional[frozenset] = None):
    """Portable shard_map with a subset of axes manual.

    jax>=0.7 exposes ``jax.shard_map(..., axis_names=manual_axes)`` and the
    non-manual axes stay auto (GSPMD).  On 0.4.x partial-auto shard_map is
    NotImplemented eagerly and miscompiles under SPMD partitioning, so the
    fallback runs FULL-manual with ``check_rep=False``: replicated in_specs
    become redundant per-device compute over the would-be-auto axes —
    numerically identical, and correct on single-host CPU meshes."""
    if manual_axes is None:
        manual_axes = frozenset(mesh.axis_names)
    new_sm = getattr(jax, "shard_map", None)
    if new_sm is not None:
        try:
            return new_sm(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs,
                          axis_names=frozenset(manual_axes))
        except TypeError:  # pragma: no cover (axis_names kwarg renamed)
            pass
    from jax.experimental.shard_map import shard_map as old_sm
    return old_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def donating_jit(fun, *, donate_argnums=(), static_argnames=None):
    """``jax.jit(fun, donate_argnums=...)`` that stays quiet on backends
    where donation is unimplemented.

    CPU jax (the 0.4.x floor) cannot donate input buffers and emits a
    "Some donated buffers were not usable" UserWarning on every call; on
    TPU the same donation halves the peak cache footprint of the serving
    hot loop.  Callers treat donation as a hint: every donated argument is
    rebound to the returned value, so the suppressed warning is the only
    backend-visible difference."""
    import warnings

    kwargs = {}
    if static_argnames is not None:
        kwargs["static_argnames"] = static_argnames
    jitted = jax.jit(fun, donate_argnums=tuple(donate_argnums), **kwargs)

    @functools.wraps(fun)
    def call(*args, **kw):
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message=".*[Dd]onated buffers.*",
                category=UserWarning)
            return jitted(*args, **kw)

    call.lower = jitted.lower
    return call


def pcast_varying(x, axes):
    """``lax.pcast(x, axes, to="varying")`` where the varying-axes system
    exists; identity on older jax (full-manual shard_map has no replication
    tracking to inform)."""
    from jax import lax
    if hasattr(lax, "pcast"):
        return lax.pcast(x, tuple(axes), to="varying")
    if hasattr(lax, "pvary"):
        return lax.pvary(x, tuple(axes))
    return x


__all__ = [
    "SUPPORTED_RANGE", "jax_version", "backend", "on_tpu",
    "tpu_compiler_params", "vmem_scratch", "smem_scratch",
    "prefetch_grid_spec",
    "make_mesh", "make_mesh_on", "use_mesh", "make_abstract_mesh",
    "mesh_axis_size",
    "shard_map", "pcast_varying", "PartitionSpec", "donating_jit",
]
