"""Version-portability + kernel-dispatch subsystem.

``repro.backend.compat``   — the ONLY place jax version differences live
                             (pltpu symbols, mesh/AbstractMesh/shard_map
                             API splits; supported range 0.4.37 — 0.7.x).
``repro.backend.dispatch`` — the kernel-dispatch front door selecting
                             Pallas-TPU / Pallas-interpret / jnp-reference
                             per detected backend (``REPRO_KERNELS``
                             overrides).
"""
from repro.backend import compat, dispatch
from repro.backend.compat import (make_abstract_mesh, make_mesh,
                                  mesh_axis_size, use_mesh)
from repro.backend.dispatch import (dispatch_flash_attention,
                                    dispatch_layernorm, dispatch_linear_scan,
                                    dispatch_matmul, kernel_path)

__all__ = [
    "compat", "dispatch", "make_mesh", "make_abstract_mesh",
    "mesh_axis_size", "use_mesh", "kernel_path", "dispatch_matmul",
    "dispatch_flash_attention", "dispatch_linear_scan", "dispatch_layernorm",
]
