"""Fused matmul + epilogue Pallas TPU kernel.

SSR fuses reuse-distance-1 ops (bias add, Reformat dtype casts, activation,
Transpose-free layouts) into the HMM matmul epilogue so they never occupy a
separate accelerator or an extra HBM round trip.  The TPU equivalent: a
tiled MXU matmul whose VMEM-resident fp32 accumulator gets bias + activation
+ down-cast applied in the epilogue before the single HBM write-back.

Grid (M/bm, N/bn, K/bk); K is the sequential accumulation dimension.
Block shapes default to MXU-aligned 128 multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.backend import compat


def _epilogue(acc, bias, activation):
    if bias is not None:
        acc = acc + bias
    if activation == "gelu":
        acc = jax.nn.gelu(acc, approximate=True)
    elif activation == "silu":
        acc = jax.nn.silu(acc)
    elif activation == "relu2":
        acc = jnp.square(jnp.maximum(acc, 0.0))
    elif activation != "none":
        raise ValueError(activation)
    return acc


def _mm_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, activation, k_blocks):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kb == k_blocks - 1)
    def _done():
        bias = b_ref[...].astype(jnp.float32) if b_ref is not None else None
        o_ref[...] = _epilogue(acc_ref[...], bias, activation).astype(
            o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k",
                     "out_dtype", "interpret"))
def matmul_fused(x, w, bias=None, *, activation="none", block_m=256,
                 block_n=256, block_k=512, out_dtype=None, interpret=False):
    """x: (M, K) @ w: (K, N) [+ bias (N,)] with fused epilogue -> (M, N)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bn, bk = (min(block_m, m), min(block_n, n), min(block_k, k))
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    out_dtype = out_dtype or x.dtype
    k_blocks = k // bk

    kernel = functools.partial(_mm_kernel, activation=activation,
                               k_blocks=k_blocks)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
        pl.BlockSpec((bk, bn), lambda i, j, kb: (kb, j)),
    ]
    args = [x, w]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kb: (0, j)))
        args.append(bias.reshape(1, n))
    else:
        # pallas needs a concrete operand list; pass a dummy 0-bias row.
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kb: (0, j)))
        args.append(jnp.zeros((1, n), x.dtype))

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[compat.vmem_scratch((bm, bn), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
