"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
used by the per-kernel sweep tests and by the CPU execution path)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, q_pos, k_pos, k_valid, *, causal=True,
                        window=0, softcap=0.0):
    """q: (B,H,Sq,D), k/v: (B,Hkv,Skv,D) -> (B,H,Sq,D).  Plain softmax."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    groups = h // hkv
    k = jnp.repeat(k, groups, axis=1)
    v = jnp.repeat(v, groups, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    rel = q_pos[:, None] - k_pos[None, :]
    ok = k_valid[None, :] > 0
    if causal:
        ok &= rel >= 0
    if window > 0:
        ok &= rel < window
    s = jnp.where(ok, s, NEG_INF)
    # guard fully-masked rows like the kernel (output 0, not nan)
    any_ok = jnp.any(ok, axis=-1)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    out = jnp.where(any_ok[None, None, :, None], out, 0.0)
    return out.astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        softcap=0.0):
    """Decode attention over a paged KV cache, pure jnp.

    q: (B, Hkv, G, D) — one query token per slot, q heads grouped per kv
    head; k_pages/v_pages: (N, P, Hkv, D) physical block pool;
    block_tables: (B, NB) int32 logical->physical map (entries >= N are
    unmapped: clipped to a garbage page and masked); lengths: (B,) valid
    tokens per slot (the query sits at position lengths-1).
    Returns (B, Hkv, G, D).

    The gathered layout is logical-ordered, so key position == gather row
    and the length mask reproduces exactly the dense path's masking: the
    valid keys are summed in the same order with the same f32 softmax, so
    paged decode is bit-identical to dense decode.
    """
    b, hk, g, d = q.shape
    n, p, _, _ = k_pages.shape
    nb = block_tables.shape[1]
    dt = q.dtype
    bt = jnp.clip(block_tables, 0, n - 1)
    k = k_pages[bt].reshape(b, nb * p, hk, d)         # (B, T, Hkv, D)
    v = v_pages[bt].reshape(b, nb * p, hk, d)
    s = jnp.einsum("bhgd,bthd->bhgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    ok = jnp.arange(nb * p)[None, :] < lengths[:, None]   # (B, T)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", probs.astype(dt), v.astype(dt))
    return out.astype(dt)


def paged_prefill_attention_ref(q, k_pages, v_pages, block_tables, offset,
                                *, softcap=0.0):
    """Suffix/chunked prefill attention over a paged KV cache, pure jnp.

    q: (B, Hkv, G, S, D) — S fresh query tokens per slot sitting at
    positions ``offset .. offset+S-1``, q heads grouped per kv head;
    k_pages/v_pages: (N, P, Hkv, D) physical block pool (the fresh
    chunk's K/V are already written in); block_tables: (B, NB) int32
    logical->physical map over *all* mapped blocks — shared prefix and
    fresh suffix alike (entries >= N are unmapped: clipped to a garbage
    page and causally masked); offset: () int32 position of the first
    fresh query.  Returns (B, Hkv, G, S, D).

    The mask is purely causal (``kpos <= qpos``): a suffix query attends
    every earlier cached position — the reused prefix — plus the fresh
    chunk up to itself.  The gathered layout is logical-ordered, so key
    position == gather row and the valid keys reduce in the same order
    with the same f32 softmax as the dense path; masked rows contribute
    exact zeros, so suffix-only prefill decodes token-identically to a
    cold full prefill.
    """
    b, hk, g, s, d = q.shape
    n, p, _, _ = k_pages.shape
    nb = block_tables.shape[1]
    dt = q.dtype
    bt = jnp.clip(block_tables, 0, n - 1)
    k = k_pages[bt].reshape(b, nb * p, hk, d)         # (B, T, Hkv, D)
    v = v_pages[bt].reshape(b, nb * p, hk, d)
    sc = jnp.einsum("bhgsd,bthd->bhgst", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(d)
    if softcap > 0:
        sc = softcap * jnp.tanh(sc / softcap)
    qpos = offset + jnp.arange(s)                     # (S,)
    kpos = jnp.arange(nb * p)                         # (T,)
    ok = kpos[None, :] <= qpos[:, None]               # (S, T)
    sc = jnp.where(ok[None, None, None, :, :], sc, NEG_INF)
    probs = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgst,bthd->bhgsd", probs.astype(dt), v.astype(dt))
    return out.astype(dt)


def paged_verify_attention_ref(q, k_pages, v_pages, block_tables, offset,
                               *, softcap=0.0):
    """Speculative-verify attention over a paged KV cache, pure jnp.

    Identical to ``paged_prefill_attention_ref`` except ``offset`` is a
    PER-SLOT ``(B,)`` vector: each slot's S-token verify window (current
    token + K drafted tokens) sits at its own positions
    ``offset[b] .. offset[b]+S-1`` — slots verify at different depths in
    one batched step, exactly as the decode path's per-slot position
    vector allows.

    q: (B, Hkv, G, S, D); k_pages/v_pages: (N, P, Hkv, D) with the
    window's K/V already written in; block_tables: (B, NB);
    offset: (B,) int32.  Returns (B, Hkv, G, S, D).

    Same logical-ordered gather, causal mask, and f32 softmax as the
    other paged refs — so greedy verification scores each window
    position exactly as a sequential one-token decode would, which is
    what keeps accepted speculative tokens bit-identical to the one-shot
    greedy stream.
    """
    b, hk, g, s, d = q.shape
    n, p, _, _ = k_pages.shape
    nb = block_tables.shape[1]
    dt = q.dtype
    bt = jnp.clip(block_tables, 0, n - 1)
    k = k_pages[bt].reshape(b, nb * p, hk, d)         # (B, T, Hkv, D)
    v = v_pages[bt].reshape(b, nb * p, hk, d)
    sc = jnp.einsum("bhgsd,bthd->bhgst", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(d)
    if softcap > 0:
        sc = softcap * jnp.tanh(sc / softcap)
    qpos = offset[:, None] + jnp.arange(s)[None, :]   # (B, S)
    kpos = jnp.arange(nb * p)                         # (T,)
    ok = kpos[None, None, :] <= qpos[:, :, None]      # (B, S, T)
    sc = jnp.where(ok[:, None, None, :, :], sc, NEG_INF)
    probs = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgst,bthd->bhgsd", probs.astype(dt), v.astype(dt))
    return out.astype(dt)


def matmul_fused_ref(x, w, bias=None, *, activation="none", out_dtype=None):
    acc = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    if activation == "gelu":
        acc = jax.nn.gelu(acc, approximate=True)
    elif activation == "silu":
        acc = jax.nn.silu(acc)
    elif activation == "relu2":
        acc = jnp.square(jnp.maximum(acc, 0.0))
    return acc.astype(out_dtype or x.dtype)


def norm_onepass_ref(x, scale, bias=None, *, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * scale.astype(jnp.float32) + (0 if bias is None
                                             else bias.astype(jnp.float32))
    else:
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype)


def linear_scan_ref(a, b, h0=None):
    """a, b: (N, S, F) -> all states (N, S, F) via a plain sequential scan."""
    n, s, f = a.shape
    if h0 is None:
        h0 = jnp.zeros((n, f), jnp.float32)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    a_t = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    b_t = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32), (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype)
