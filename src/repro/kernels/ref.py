"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
used by the per-kernel sweep tests and by the CPU execution path)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_rope_ref(x, positions, theta):
    """Standard (non-M) RoPE, op-for-op the model layer's math.

    x: (B, S, H, D); positions: (B, S).  This mirrors
    ``layers.apply_rope``'s no-mrope branch exactly — same op order, same
    f32 casts — so the fused decode path that ropes inside the kernel can
    be pinned bit-identical to the layer-side rotation on fp paths.
    """
    b, s, h, d = x.shape
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    angles = positions.astype(jnp.float32)[..., None] * inv   # (B,S,d/2)
    cos = jnp.cos(angles)[:, :, None, :]                      # (B,S,1,d/2)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def quantize_int8_rows(x):
    """Symmetric per-row int8 quantization over the last axis.

    x: (..., D) -> (q int8 (..., D), scale f32 (...,)).  Zero rows get
    scale 1 so dequant is exact (all-zero stays all-zero)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(amax > 0.0, amax / 127.0, 1.0)
    q = jnp.round(xf / scale[..., None]).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    """Inverse of ``quantize_int8_rows``: (..., D) int8 + (...,) f32."""
    return q.astype(jnp.float32) * scale[..., None]


def _gather_pages(k_pages, v_pages, bt, k_scales, v_scales, b, nb, p, hk, d):
    """Gather (and dequantize, when scales are given) pool pages through
    clipped block tables into logical-ordered (B, NB*P, Hkv, D) f32."""
    k = k_pages[bt].reshape(b, nb * p, hk, d).astype(jnp.float32)
    v = v_pages[bt].reshape(b, nb * p, hk, d).astype(jnp.float32)
    if k_scales is not None:
        k = k * k_scales[bt].reshape(b, nb * p, hk)[..., None]
        v = v * v_scales[bt].reshape(b, nb * p, hk)[..., None]
    return k, v


def flash_attention_ref(q, k, v, q_pos, k_pos, k_valid, *, causal=True,
                        window=0, softcap=0.0):
    """q: (B,H,Sq,D), k/v: (B,Hkv,Skv,D) -> (B,H,Sq,D).  Plain softmax."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    groups = h // hkv
    k = jnp.repeat(k, groups, axis=1)
    v = jnp.repeat(v, groups, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    rel = q_pos[:, None] - k_pos[None, :]
    ok = k_valid[None, :] > 0
    if causal:
        ok &= rel >= 0
    if window > 0:
        ok &= rel < window
    s = jnp.where(ok, s, NEG_INF)
    # guard fully-masked rows like the kernel (output 0, not nan)
    any_ok = jnp.any(ok, axis=-1)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    out = jnp.where(any_ok[None, None, :, None], out, 0.0)
    return out.astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        softcap=0.0, k_scales=None, v_scales=None):
    """Decode attention over a paged KV cache, pure jnp.

    q: (B, Hkv, G, D) — one query token per slot, q heads grouped per kv
    head; k_pages/v_pages: (N, P, Hkv, D) physical block pool;
    block_tables: (B, NB) int32 logical->physical map (entries >= N are
    unmapped: clipped to a garbage page and masked); lengths: (B,) valid
    tokens per slot (the query sits at position lengths-1).
    k_scales/v_scales: (N, P, Hkv) f32 per-row dequant scales when the
    pool holds int8 blocks (None on fp pools).
    Returns (B, Hkv, G, D).

    The gathered layout is logical-ordered, so key position == gather row
    and the length mask reproduces exactly the dense path's masking: the
    valid keys are summed in the same order with the same f32 softmax, so
    paged decode is bit-identical to dense decode.
    """
    b, hk, g, d = q.shape
    n, p, _, _ = k_pages.shape
    nb = block_tables.shape[1]
    dt = q.dtype
    bt = jnp.clip(block_tables, 0, n - 1)
    k, v = _gather_pages(k_pages, v_pages, bt, k_scales, v_scales,
                         b, nb, p, hk, d)             # (B, T, Hkv, D)
    s = jnp.einsum("bhgd,bthd->bhgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    ok = jnp.arange(nb * p)[None, :] < lengths[:, None]   # (B, T)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", probs.astype(dt), v.astype(dt))
    return out.astype(dt)


def fused_paged_decode_ref(q, k_new, v_new, k_pages, v_pages, block_tables,
                           positions, *, theta, softcap=0.0,
                           k_scales=None, v_scales=None):
    """RoPE + page-write + decode attention in one step, pure jnp — the
    oracle for the fused Pallas decode kernel.

    q: (B, Hkv, G, D) UN-roped grouped queries; k_new/v_new: (B, Hkv, D)
    the slot's un-roped fresh K/V projection; k_pages/v_pages:
    (N, P, Hkv, D) pool; block_tables: (B, NB) int32 (in-range — the
    manager's sentinel rows point at the sink page); positions: (B,)
    int32 write position per slot (= tokens already cached).
    k_scales/v_scales: (N, P, Hkv) f32 on int8 pools (None on fp).

    Composes exactly the unfused model-layer sequence: rope q and k_new
    at ``positions`` (``decode_rope_ref`` — bit-identical to
    ``layers.apply_rope``), scatter the fresh row into its page
    (quantizing on int8 pools), then run ``paged_attention_ref`` at
    ``lengths = positions + 1``.  Returns
    (out (B, Hkv, G, D), k_pages, v_pages, k_scales, v_scales).
    """
    b, hk, g, d = q.shape
    n, page = k_pages.shape[:2]
    nb = block_tables.shape[1]
    cdt = k_pages.dtype
    pos_bs = positions[:, None]                       # (B, 1)
    qr = decode_rope_ref(q.reshape(b, 1, hk * g, d), pos_bs,
                         theta).reshape(b, hk, g, d)
    kr = decode_rope_ref(k_new[:, None], pos_bs, theta)[:, 0]  # (B,Hkv,D)

    blk = jnp.clip(positions // page, 0, nb - 1)
    pages = jnp.take_along_axis(block_tables, blk[:, None], axis=1)[:, 0]
    rows = positions % page
    if k_scales is not None:
        kq, ks = quantize_int8_rows(kr)
        vq, vs = quantize_int8_rows(v_new)
        k_pages = k_pages.at[pages, rows].set(kq, mode="drop")
        v_pages = v_pages.at[pages, rows].set(vq, mode="drop")
        k_scales = k_scales.at[pages, rows].set(ks, mode="drop")
        v_scales = v_scales.at[pages, rows].set(vs, mode="drop")
    else:
        k_pages = k_pages.at[pages, rows].set(kr.astype(cdt), mode="drop")
        v_pages = v_pages.at[pages, rows].set(v_new.astype(cdt),
                                              mode="drop")
    out = paged_attention_ref(qr, k_pages, v_pages, block_tables,
                              positions + 1, softcap=softcap,
                              k_scales=k_scales, v_scales=v_scales)
    return out, k_pages, v_pages, k_scales, v_scales


def paged_prefill_attention_ref(q, k_pages, v_pages, block_tables, offset,
                                *, softcap=0.0, k_scales=None,
                                v_scales=None):
    """Suffix/chunked prefill attention over a paged KV cache, pure jnp.

    q: (B, Hkv, G, S, D) — S fresh query tokens per slot sitting at
    positions ``offset .. offset+S-1``, q heads grouped per kv head;
    k_pages/v_pages: (N, P, Hkv, D) physical block pool (the fresh
    chunk's K/V are already written in); block_tables: (B, NB) int32
    logical->physical map over *all* mapped blocks — shared prefix and
    fresh suffix alike (entries >= N are unmapped: clipped to a garbage
    page and causally masked); offset: () int32 position of the first
    fresh query.  Returns (B, Hkv, G, S, D).

    The mask is purely causal (``kpos <= qpos``): a suffix query attends
    every earlier cached position — the reused prefix — plus the fresh
    chunk up to itself.  The gathered layout is logical-ordered, so key
    position == gather row and the valid keys reduce in the same order
    with the same f32 softmax as the dense path; masked rows contribute
    exact zeros, so suffix-only prefill decodes token-identically to a
    cold full prefill.
    """
    b, hk, g, s, d = q.shape
    n, p, _, _ = k_pages.shape
    nb = block_tables.shape[1]
    dt = q.dtype
    bt = jnp.clip(block_tables, 0, n - 1)
    k, v = _gather_pages(k_pages, v_pages, bt, k_scales, v_scales,
                         b, nb, p, hk, d)             # (B, T, Hkv, D)
    sc = jnp.einsum("bhgsd,bthd->bhgst", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(d)
    if softcap > 0:
        sc = softcap * jnp.tanh(sc / softcap)
    qpos = offset + jnp.arange(s)                     # (S,)
    kpos = jnp.arange(nb * p)                         # (T,)
    ok = kpos[None, :] <= qpos[:, None]               # (S, T)
    sc = jnp.where(ok[None, None, None, :, :], sc, NEG_INF)
    probs = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgst,bthd->bhgsd", probs.astype(dt), v.astype(dt))
    return out.astype(dt)


def paged_verify_attention_ref(q, k_pages, v_pages, block_tables, offset,
                               *, softcap=0.0, k_scales=None,
                               v_scales=None):
    """Speculative-verify attention over a paged KV cache, pure jnp.

    Identical to ``paged_prefill_attention_ref`` except ``offset`` is a
    PER-SLOT ``(B,)`` vector: each slot's S-token verify window (current
    token + K drafted tokens) sits at its own positions
    ``offset[b] .. offset[b]+S-1`` — slots verify at different depths in
    one batched step, exactly as the decode path's per-slot position
    vector allows.

    q: (B, Hkv, G, S, D); k_pages/v_pages: (N, P, Hkv, D) with the
    window's K/V already written in; block_tables: (B, NB);
    offset: (B,) int32.  Returns (B, Hkv, G, S, D).

    Same logical-ordered gather, causal mask, and f32 softmax as the
    other paged refs — so greedy verification scores each window
    position exactly as a sequential one-token decode would, which is
    what keeps accepted speculative tokens bit-identical to the one-shot
    greedy stream.
    """
    b, hk, g, s, d = q.shape
    n, p, _, _ = k_pages.shape
    nb = block_tables.shape[1]
    dt = q.dtype
    bt = jnp.clip(block_tables, 0, n - 1)
    k, v = _gather_pages(k_pages, v_pages, bt, k_scales, v_scales,
                         b, nb, p, hk, d)             # (B, T, Hkv, D)
    sc = jnp.einsum("bhgsd,bthd->bhgst", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(d)
    if softcap > 0:
        sc = softcap * jnp.tanh(sc / softcap)
    qpos = offset[:, None] + jnp.arange(s)[None, :]   # (B, S)
    kpos = jnp.arange(nb * p)                         # (T,)
    ok = kpos[None, None, :] <= qpos[:, :, None]      # (B, S, T)
    sc = jnp.where(ok[:, None, None, :, :], sc, NEG_INF)
    probs = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgst,bthd->bhgsd", probs.astype(dt), v.astype(dt))
    return out.astype(dt)


def matmul_fused_ref(x, w, bias=None, *, activation="none", out_dtype=None):
    acc = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    if activation == "gelu":
        acc = jax.nn.gelu(acc, approximate=True)
    elif activation == "silu":
        acc = jax.nn.silu(acc)
    elif activation == "relu2":
        acc = jnp.square(jnp.maximum(acc, 0.0))
    return acc.astype(out_dtype or x.dtype)


def norm_onepass_ref(x, scale, bias=None, *, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * scale.astype(jnp.float32) + (0 if bias is None
                                             else bias.astype(jnp.float32))
    else:
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype)


def linear_scan_ref(a, b, h0=None):
    """a, b: (N, S, F) -> all states (N, S, F) via a plain sequential scan."""
    n, s, f = a.shape
    if h0 is None:
        h0 = jnp.zeros((n, f), jnp.float32)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    a_t = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    b_t = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32), (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype)
