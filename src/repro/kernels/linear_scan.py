"""Gated linear recurrence Pallas TPU kernel:  h_t = a_t * h_{t-1} + b_t.

Backs the Mamba selective scan (jamba) and the xLSTM recurrences.  The GPU
formulation (CUDA selective-scan with warp shuffles) does not transfer;
the TPU-native shape is: grid over (rows, feature blocks), sequential over
sequence blocks, with the carry h held in VMEM scratch — the sequence
streams HBM→VMEM once and states never rematerialize in HBM (the same
"single streaming pass with carried state" idea as SSR's line buffer).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.backend import compat


def _scan_kernel(a_ref, b_ref, h0_ref, o_ref, h_ref, *, block_s):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)  # (1, bf)

    a = a_ref[0].astype(jnp.float32)                 # (bs, bf)
    b = b_ref[0].astype(jnp.float32)
    h = h_ref[...]                                   # (1, bf)

    def step(t, carry):
        h, out = carry
        h = a[t][None, :] * h + b[t][None, :]
        out = jax.lax.dynamic_update_slice(out, h, (t, 0))
        return h, out

    out0 = jnp.zeros((block_s, a.shape[1]), jnp.float32)
    h, out = jax.lax.fori_loop(0, block_s, step, (h, out0))
    h_ref[...] = h
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "block_f",
                                             "interpret"))
def linear_scan(a, b, h0=None, *, block_s=256, block_f=512, interpret=False):
    """a, b: (N, S, F); h0: (N, F) or None.  Returns h_all (N, S, F)."""
    n, s, f = a.shape
    if h0 is None:
        h0 = jnp.zeros((n, f), jnp.float32)
    bs = min(block_s, s)
    bf = min(block_f, f)
    assert s % bs == 0 and f % bf == 0, (s, f, bs, bf)

    kernel = functools.partial(_scan_kernel, block_s=bs)
    # NB: the sequential (sequence) dimension is the *last* grid dim so the
    # VMEM carry scratch persists correctly between consecutive steps.
    return pl.pallas_call(
        kernel,
        grid=(n, f // bf, s // bs),
        in_specs=[
            pl.BlockSpec((1, bs, bf), lambda i, fb, sb: (i, sb, fb)),
            pl.BlockSpec((1, bs, bf), lambda i, fb, sb: (i, sb, fb)),
            pl.BlockSpec((1, bf), lambda i, fb, sb: (i, fb)),
        ],
        out_specs=pl.BlockSpec((1, bs, bf), lambda i, fb, sb: (i, sb, fb)),
        out_shape=jax.ShapeDtypeStruct((n, s, f), a.dtype),
        scratch_shapes=[compat.vmem_scratch((1, bf), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, h0)
