"""One-pass LayerNorm / RMSNorm Pallas TPU kernel.

SSR's line-buffer LayerNorm overlaps the mean pass with the variance pass so
the data is read once from the producer stream.  On TPU the analogue is a
single HBM read per row block: the row lives in VMEM while mean, variance,
and the normalized output are all computed — one read, one write, no second
pass over HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(x_ref, scale_ref, bias_ref, o_ref, *, kind, eps):
    x = x_ref[...].astype(jnp.float32)               # (br, d)
    if kind == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + eps)
        y = y * scale_ref[...].astype(jnp.float32) \
            + bias_ref[...].astype(jnp.float32)
    else:  # rmsnorm
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kind", "eps", "block_rows",
                                             "interpret"))
def norm_onepass(x, scale, bias=None, *, kind="rmsnorm", eps=1e-6,
                 block_rows=256, interpret=False):
    """x: (R, D) row-normalized -> (R, D)."""
    r, d = x.shape
    br = min(block_rows, r)
    assert r % br == 0, (r, br)
    if bias is None:
        bias = jnp.zeros((d,), x.dtype)
    kernel = functools.partial(_ln_kernel, kind=kind, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, scale.reshape(1, d), bias.reshape(1, d))
