"""Paged decode attention Pallas TPU kernel.

Decode attention where K/V live in a physical block pool
(``(num_blocks, page_size, kv_heads, head_dim)``) addressed through
per-slot block tables, instead of one dense ``(slots, max_seq, ...)``
reservation.  The block table rides the grid as a **scalar-prefetch**
operand: each grid step's ``index_map`` reads ``bt[b, j]`` to DMA the
j-th *logical* page of slot ``b`` straight from wherever it physically
sits — the gather never materializes a contiguous K/V copy in HBM, which
is the whole point (the SSR spatial story applied to memory: decode
replicas stay busy because their KV footprint is live-token-sized).

Layout: q ``(B, Hkv, G, D)`` (query heads grouped per kv head — GQA runs
as one MXU matmul per kv head); pages ``(N, P, Hkv, D)``; block tables
``(B, NB)`` int32 (host pre-clips unmapped entries into range — rows past
``lengths`` are masked anyway); lengths ``(B,)`` = tokens valid per slot.

Grid: ``(B, Hkv, NB)`` with the page dimension sequential ("arbitrary"):
online-softmax (m, l, acc) statistics carry across pages in VMEM scratch,
exactly like ``flash_attention.py`` carries them across KV blocks.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.backend import compat

NEG_INF = -1e30


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref,   # ins
                  o_ref,                                  # outs
                  acc_ref, m_ref, l_ref,                  # scratch
                  *, scale, softcap, page, nb):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)           # (P, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    # rows of logical page j hold positions j*P + i; valid below length.
    # the query sits at position length-1, so the length mask subsumes
    # causality — every valid cached key is attendable.
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    ok = pos < len_ref[b]
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0, ...] = (acc_ref[...] / safe).astype(o_ref.dtype)


def _paged_prefill_kernel(bt_ref, off_ref, q_ref, k_ref, v_ref,   # ins
                          o_ref,                                  # outs
                          acc_ref, m_ref, l_ref,                  # scratch
                          *, scale, softcap, page, nb, g, s):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # (G, S, D) -> (G*S, D): one query row per (group, position) pair so
    # the whole chunk runs as a single MXU matmul per kv head per page.
    d = q_ref.shape[-1]
    q = q_ref[0, 0].astype(jnp.float32).reshape(g * s, d)
    k = k_ref[0, :, 0].astype(jnp.float32)           # (P, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        sc = softcap * jnp.tanh(sc / softcap)

    # rows of logical page j hold positions j*P + i; row r of the flat
    # query block sits at position offset + (r % S).  Pure causal mask:
    # a suffix query attends the whole reused prefix plus itself.
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    qpos = off_ref[0] + (
        jax.lax.broadcasted_iota(jnp.int32, (g * s, 1), 0) % s)
    ok = pos <= qpos                                 # (G*S, P)
    sc = jnp.where(ok, sc, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(sc, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(sc - m_new)
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0, ...] = (acc_ref[...] / safe).astype(
            o_ref.dtype).reshape(g, s, d)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_prefill_attention_grouped(q, k_pages, v_pages, block_tables,
                                    offset, *, softcap=0.0,
                                    interpret=False):
    """Suffix/chunked prefill over the paged pool: q (B, Hkv, G, S, D)
    holds S fresh tokens at positions offset..offset+S-1 (K/V already
    written into the pool); block_tables (B, NB) int32 (in-range) maps
    every logical block — shared prefix and fresh suffix; offset () int32.
    Returns (B, Hkv, G, S, D).  Same page-sequential online-softmax walk
    as decode, with the causal mask replacing the length mask."""
    b, hk, g, s, d = q.shape
    n, page, _, _ = k_pages.shape
    nb = block_tables.shape[1]
    scale = 1.0 / math.sqrt(d)

    grid_spec = compat.prefetch_grid_spec(
        num_scalar_prefetch=2,           # block tables + offset
        grid=(b, hk, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g, s, d),
                         lambda b_, h_, j, bt, off: (b_, h_, 0, 0, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h_, j, bt, off: (bt[b_, j], 0, h_, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h_, j, bt, off: (bt[b_, j], 0, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, s, d),
                               lambda b_, h_, j, bt, off: (b_, h_, 0, 0, 0)),
        scratch_shapes=[
            compat.vmem_scratch((g * s, d), jnp.float32),
            compat.vmem_scratch((g * s, 1), jnp.float32),
            compat.vmem_scratch((g * s, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_prefill_kernel, scale=scale,
                               softcap=softcap, page=page, nb=nb, g=g, s=s)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, g, s, d), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32),
      jnp.asarray(offset, jnp.int32).reshape(1),
      q, k_pages, v_pages)


def _fused_decode_kernel(bt_ref, pos_ref, q_ref, kn_ref, vn_ref,
                         kp_ref, vp_ref, *rest,
                         scale, softcap, theta, page, nb, quantized):
    """RoPE + page-write + decode attention, one pass over the pool.

    Scalar-prefetch: block tables (B, NB) + write positions (B,).  The
    pool blocks arrive through the same ``bt[b, j]`` index maps as the
    unfused kernel; the OUTPUT pool blocks alias the inputs
    (``input_output_aliases``) with a j-constant index map pinned to the
    slot's write page ``bt[b, pos // page]`` — each (slot, head) writes
    exactly one page (the input content with the fresh roped row
    substituted) and every other page rides through the alias untouched.
    RoPE runs in-kernel as ``x * cos + (x @ R) * sin`` with the
    rotate-half matrix R built from 2D iotas, so the fresh K and the
    query never round-trip HBM between rotation and attention.  On int8
    pools (``quantized``) the page blocks carry per-row scales: pages
    dequantize before the matmul and the fresh row quantizes in-kernel."""
    if quantized:
        (ks_ref, vs_ref, o_ref, ko_ref, vo_ref, kso_ref, vso_ref,
         acc_ref, m_ref, l_ref) = rest
    else:
        o_ref, ko_ref, vo_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(2)
    pos = pos_ref[b]
    jt = jnp.minimum(pos // page, nb - 1)
    row_t = pos % page

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    d = q_ref.shape[-1]
    half = d // 2
    # RoPE tables, full-D layout: column c rotates at frequency
    # theta^(-2*(c % half)/d) — the ref's inv_freq duplicated per half.
    col = jax.lax.broadcasted_iota(jnp.int32, (1, d), 1)
    inv = jnp.exp((col % half).astype(jnp.float32)
                  * jnp.float32(-2.0 * math.log(theta) / d))
    ang = pos.astype(jnp.float32) * inv
    cosf, sinf = jnp.cos(ang), jnp.sin(ang)
    # rotate-half as a matmul: R[c+half, c] = -1, R[c-half, c] = +1
    rr = jax.lax.broadcasted_iota(jnp.int32, (d, d), 0)
    rc = jax.lax.broadcasted_iota(jnp.int32, (d, d), 1)
    rot = jnp.where(rr == rc + half, -1.0, 0.0) \
        + jnp.where(rr + half == rc, 1.0, 0.0)

    def rope(x):
        return x * cosf + jax.lax.dot_general(
            x, rot, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * sinf

    qr = rope(q_ref[0, 0].astype(jnp.float32))           # (G, D)
    knr = rope(kn_ref[0].astype(jnp.float32))            # (1, D)
    vn = vn_ref[0].astype(jnp.float32)                   # (1, D)

    rows = jax.lax.broadcasted_iota(jnp.int32, (page, 1), 0)
    fresh = (j == jt) & (rows == row_t)                  # (page, 1)

    if quantized:
        k_amax = jnp.max(jnp.abs(knr), axis=1, keepdims=True)
        v_amax = jnp.max(jnp.abs(vn), axis=1, keepdims=True)
        k_sc = jnp.where(k_amax > 0.0, k_amax / 127.0, 1.0)   # (1, 1)
        v_sc = jnp.where(v_amax > 0.0, v_amax / 127.0, 1.0)
        knq = jnp.round(knr / k_sc).astype(jnp.int8)
        vnq = jnp.round(vn / v_sc).astype(jnp.int8)
        # attention reads what the cache will hold: the dequantized row
        k = jnp.where(fresh, knq.astype(jnp.float32) * k_sc,
                      kp_ref[0, :, 0].astype(jnp.float32) * ks_ref[0])
        v = jnp.where(fresh, vnq.astype(jnp.float32) * v_sc,
                      vp_ref[0, :, 0].astype(jnp.float32) * vs_ref[0])

        @pl.when(j == jt)
        def _write_q():
            ko_ref[0, :, 0] = jnp.where(fresh, knq, kp_ref[0, :, 0])
            vo_ref[0, :, 0] = jnp.where(fresh, vnq, vp_ref[0, :, 0])
            kso_ref[0] = jnp.where(fresh, k_sc, ks_ref[0])
            vso_ref[0] = jnp.where(fresh, v_sc, vs_ref[0])
    else:
        cdt = kp_ref.dtype
        knc = knr.astype(cdt)
        vnc = vn.astype(cdt)
        k = jnp.where(fresh, knc.astype(jnp.float32),
                      kp_ref[0, :, 0].astype(jnp.float32))
        v = jnp.where(fresh, vnc.astype(jnp.float32),
                      vp_ref[0, :, 0].astype(jnp.float32))

        @pl.when(j == jt)
        def _write():
            ko_ref[0, :, 0] = jnp.where(fresh, knc, kp_ref[0, :, 0])
            vo_ref[0, :, 0] = jnp.where(fresh, vnc, vp_ref[0, :, 0])

    s = jax.lax.dot_general(qr, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    ok = kpos <= pos              # length mask at pos+1 (fresh row included)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0, ...] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("theta", "softcap", "interpret"))
def fused_paged_decode_grouped(q, k_new, v_new, k_pages, v_pages,
                               block_tables, positions, *, theta,
                               softcap=0.0, k_scales=None, v_scales=None,
                               interpret=False):
    """Fused RoPE + page-write + decode attention over the paged pool.

    q: (B, Hkv, G, D) un-roped; k_new/v_new: (B, Hkv, D) un-roped fresh
    K/V; k_pages/v_pages: (N, P, Hkv, D); block_tables: (B, NB) int32
    (in-range); positions: (B,) int32 write position per slot.
    k_scales/v_scales: (N, P, Hkv) f32 on int8 pools (None on fp).
    Returns (out (B, Hkv, G, D), k_pages, v_pages, k_scales, v_scales) —
    the pool buffers are aliased in/out, so callers MUST rebind them.
    """
    b, hk, g, d = q.shape
    n, page, _, _ = k_pages.shape
    nb = block_tables.shape[1]
    scale = 1.0 / math.sqrt(d)
    quantized = k_scales is not None

    def page_in(b_, h_, j, bt, ps):
        return (bt[b_, j], 0, h_, 0)

    def page_out(b_, h_, j, bt, ps):
        return (bt[b_, jnp.minimum(ps[b_] // page, nb - 1)], 0, h_, 0)

    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda b_, h_, j, bt, ps: (b_, h_, 0, 0)),
        pl.BlockSpec((1, 1, d), lambda b_, h_, j, bt, ps: (b_, h_, 0)),
        pl.BlockSpec((1, 1, d), lambda b_, h_, j, bt, ps: (b_, h_, 0)),
        pl.BlockSpec((1, page, 1, d), page_in),
        pl.BlockSpec((1, page, 1, d), page_in),
    ]
    out_specs = [
        pl.BlockSpec((1, 1, g, d), lambda b_, h_, j, bt, ps: (b_, h_, 0, 0)),
        pl.BlockSpec((1, page, 1, d), page_out),
        pl.BlockSpec((1, page, 1, d), page_out),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, hk, g, d), q.dtype),
        jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
        jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
    ]
    # alias indices count the two scalar-prefetch operands: inputs are
    # (bt, positions, q, k_new, v_new, k_pages, v_pages[, ks, vs])
    aliases = {5: 1, 6: 2}
    operands = [q, k_new, v_new, k_pages, v_pages]
    if quantized:
        sspec_in = pl.BlockSpec((1, page, 1),
                                lambda b_, h_, j, bt, ps: (bt[b_, j], 0, h_))
        sspec_out = pl.BlockSpec(
            (1, page, 1),
            lambda b_, h_, j, bt, ps: (
                bt[b_, jnp.minimum(ps[b_] // page, nb - 1)], 0, h_))
        in_specs += [sspec_in, sspec_in]
        out_specs += [sspec_out, sspec_out]
        out_shape += [jax.ShapeDtypeStruct(k_scales.shape, k_scales.dtype),
                      jax.ShapeDtypeStruct(v_scales.shape, v_scales.dtype)]
        aliases.update({7: 3, 8: 4})
        operands += [k_scales, v_scales]

    grid_spec = compat.prefetch_grid_spec(
        num_scalar_prefetch=2,           # block tables + positions
        grid=(b, hk, nb),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            compat.vmem_scratch((g, d), jnp.float32),
            compat.vmem_scratch((g, 1), jnp.float32),
            compat.vmem_scratch((g, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(_fused_decode_kernel, scale=scale,
                               softcap=softcap, theta=theta, page=page,
                               nb=nb, quantized=quantized)
    outs = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=aliases,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), positions.astype(jnp.int32),
      *operands)
    if quantized:
        return outs
    out, kp, vp = outs
    return out, kp, vp, None, None


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_attention_grouped(q, k_pages, v_pages, block_tables, lengths, *,
                            softcap=0.0, interpret=False):
    """q: (B, Hkv, G, D); k_pages/v_pages: (N, P, Hkv, D);
    block_tables: (B, NB) int32 (in-range); lengths: (B,) int32.
    Returns (B, Hkv, G, D)."""
    b, hk, g, d = q.shape
    n, page, _, _ = k_pages.shape
    nb = block_tables.shape[1]
    scale = 1.0 / math.sqrt(d)

    grid_spec = compat.prefetch_grid_spec(
        num_scalar_prefetch=2,           # block tables + lengths
        grid=(b, hk, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b_, h_, j, bt, ln: (b_, h_, 0, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h_, j, bt, ln: (bt[b_, j], 0, h_, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h_, j, bt, ln: (bt[b_, j], 0, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, h_, j, bt, ln: (b_, h_, 0, 0)),
        scratch_shapes=[
            compat.vmem_scratch((g, d), jnp.float32),
            compat.vmem_scratch((g, 1), jnp.float32),
            compat.vmem_scratch((g, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_kernel, scale=scale, softcap=softcap,
                               page=page, nb=nb)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, g, d), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages)
