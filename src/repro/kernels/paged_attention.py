"""Paged decode attention Pallas TPU kernel.

Decode attention where K/V live in a physical block pool
(``(num_blocks, page_size, kv_heads, head_dim)``) addressed through
per-slot block tables, instead of one dense ``(slots, max_seq, ...)``
reservation.  The block table rides the grid as a **scalar-prefetch**
operand: each grid step's ``index_map`` reads ``bt[b, j]`` to DMA the
j-th *logical* page of slot ``b`` straight from wherever it physically
sits — the gather never materializes a contiguous K/V copy in HBM, which
is the whole point (the SSR spatial story applied to memory: decode
replicas stay busy because their KV footprint is live-token-sized).

Layout: q ``(B, Hkv, G, D)`` (query heads grouped per kv head — GQA runs
as one MXU matmul per kv head); pages ``(N, P, Hkv, D)``; block tables
``(B, NB)`` int32 (host pre-clips unmapped entries into range — rows past
``lengths`` are masked anyway); lengths ``(B,)`` = tokens valid per slot.

Grid: ``(B, Hkv, NB)`` with the page dimension sequential ("arbitrary"):
online-softmax (m, l, acc) statistics carry across pages in VMEM scratch,
exactly like ``flash_attention.py`` carries them across KV blocks.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.backend import compat

NEG_INF = -1e30


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref,   # ins
                  o_ref,                                  # outs
                  acc_ref, m_ref, l_ref,                  # scratch
                  *, scale, softcap, page, nb):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)           # (P, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    # rows of logical page j hold positions j*P + i; valid below length.
    # the query sits at position length-1, so the length mask subsumes
    # causality — every valid cached key is attendable.
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    ok = pos < len_ref[b]
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0, ...] = (acc_ref[...] / safe).astype(o_ref.dtype)


def _paged_prefill_kernel(bt_ref, off_ref, q_ref, k_ref, v_ref,   # ins
                          o_ref,                                  # outs
                          acc_ref, m_ref, l_ref,                  # scratch
                          *, scale, softcap, page, nb, g, s):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # (G, S, D) -> (G*S, D): one query row per (group, position) pair so
    # the whole chunk runs as a single MXU matmul per kv head per page.
    d = q_ref.shape[-1]
    q = q_ref[0, 0].astype(jnp.float32).reshape(g * s, d)
    k = k_ref[0, :, 0].astype(jnp.float32)           # (P, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        sc = softcap * jnp.tanh(sc / softcap)

    # rows of logical page j hold positions j*P + i; row r of the flat
    # query block sits at position offset + (r % S).  Pure causal mask:
    # a suffix query attends the whole reused prefix plus itself.
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    qpos = off_ref[0] + (
        jax.lax.broadcasted_iota(jnp.int32, (g * s, 1), 0) % s)
    ok = pos <= qpos                                 # (G*S, P)
    sc = jnp.where(ok, sc, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(sc, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(sc - m_new)
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0, ...] = (acc_ref[...] / safe).astype(
            o_ref.dtype).reshape(g, s, d)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_prefill_attention_grouped(q, k_pages, v_pages, block_tables,
                                    offset, *, softcap=0.0,
                                    interpret=False):
    """Suffix/chunked prefill over the paged pool: q (B, Hkv, G, S, D)
    holds S fresh tokens at positions offset..offset+S-1 (K/V already
    written into the pool); block_tables (B, NB) int32 (in-range) maps
    every logical block — shared prefix and fresh suffix; offset () int32.
    Returns (B, Hkv, G, S, D).  Same page-sequential online-softmax walk
    as decode, with the causal mask replacing the length mask."""
    b, hk, g, s, d = q.shape
    n, page, _, _ = k_pages.shape
    nb = block_tables.shape[1]
    scale = 1.0 / math.sqrt(d)

    grid_spec = compat.prefetch_grid_spec(
        num_scalar_prefetch=2,           # block tables + offset
        grid=(b, hk, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g, s, d),
                         lambda b_, h_, j, bt, off: (b_, h_, 0, 0, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h_, j, bt, off: (bt[b_, j], 0, h_, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h_, j, bt, off: (bt[b_, j], 0, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, s, d),
                               lambda b_, h_, j, bt, off: (b_, h_, 0, 0, 0)),
        scratch_shapes=[
            compat.vmem_scratch((g * s, d), jnp.float32),
            compat.vmem_scratch((g * s, 1), jnp.float32),
            compat.vmem_scratch((g * s, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_prefill_kernel, scale=scale,
                               softcap=softcap, page=page, nb=nb, g=g, s=s)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, g, s, d), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32),
      jnp.asarray(offset, jnp.int32).reshape(1),
      q, k_pages, v_pages)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_attention_grouped(q, k_pages, v_pages, block_tables, lengths, *,
                            softcap=0.0, interpret=False):
    """q: (B, Hkv, G, D); k_pages/v_pages: (N, P, Hkv, D);
    block_tables: (B, NB) int32 (in-range); lengths: (B,) int32.
    Returns (B, Hkv, G, D)."""
    b, hk, g, d = q.shape
    n, page, _, _ = k_pages.shape
    nb = block_tables.shape[1]
    scale = 1.0 / math.sqrt(d)

    grid_spec = compat.prefetch_grid_spec(
        num_scalar_prefetch=2,           # block tables + lengths
        grid=(b, hk, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b_, h_, j, bt, ln: (b_, h_, 0, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h_, j, bt, ln: (bt[b_, j], 0, h_, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h_, j, bt, ln: (bt[b_, j], 0, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, h_, j, bt, ln: (b_, h_, 0, 0)),
        scratch_shapes=[
            compat.vmem_scratch((g, d), jnp.float32),
            compat.vmem_scratch((g, 1), jnp.float32),
            compat.vmem_scratch((g, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_kernel, scale=scale, softcap=softcap,
                               page=page, nb=nb)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, g, d), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages)
