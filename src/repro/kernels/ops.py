"""Public kernel wrappers — thin aliases over the dispatch front door.

The routing decision (Pallas-TPU / Pallas-interpret / jnp reference, with
the ``REPRO_KERNELS`` override) lives in ``repro.backend.dispatch``; this
module keeps the historical ``repro.kernels.ops`` names as a stable
back-compat API for external callers and notebooks (in-repo code imports
``repro.backend.dispatch`` directly).
"""
from __future__ import annotations

from repro.backend.dispatch import (dispatch_flash_attention,
                                    dispatch_layernorm, dispatch_linear_scan,
                                    dispatch_matmul, kernel_path, use_flash)

flash_attention = dispatch_flash_attention
matmul_fused = dispatch_matmul
norm_onepass = dispatch_layernorm
linear_scan = dispatch_linear_scan

__all__ = ["flash_attention", "matmul_fused", "norm_onepass", "linear_scan",
           "use_flash", "kernel_path"]
