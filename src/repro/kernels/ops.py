"""jit'd public wrappers for the Pallas kernels with backend dispatch.

Dispatch policy (``REPRO_KERNELS`` env var):
  * "auto" (default): compiled Pallas on TPU, jnp reference elsewhere;
  * "interpret":      Pallas in interpret mode (CPU correctness testing);
  * "ref":            always the jnp reference.

Models call these wrappers, so the same model code runs the fused kernels on
TPU and the oracle path on CPU — and the kernel sweep tests compare the two.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.fused_matmul import matmul_fused as _matmul_pallas
from repro.kernels.layernorm import norm_onepass as _norm_pallas
from repro.kernels.linear_scan import linear_scan as _scan_pallas


def _mode() -> str:
    m = os.environ.get("REPRO_KERNELS", "auto")
    if m == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return {"interpret": "interpret", "ref": "ref",
            "pallas": "pallas"}.get(m, "ref")


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def use_flash(cfg, q, k) -> bool:
    """Whether the model's attention should route to the fused kernel:
    only when shapes tile cleanly to the MXU and we're not on the oracle
    path.  (The jnp fallback is itself XLA-fused on CPU.)"""
    if _mode() == "ref":
        return False
    b, s, h, d = q.shape
    t = k.shape[1]
    return (s % 8 == 0 and t % 128 == 0 and d % 128 == 0)


def flash_attention(q, k, v, *, q_pos, k_pos, k_valid=None, causal=True,
                    window=0, softcap=0.0):
    """Layout adapter: (B,S,H,D) model layout -> (B,H,S,D) kernel layout."""
    qk = jnp.swapaxes(q, 1, 2)
    kk = jnp.swapaxes(k, 1, 2)
    vk = jnp.swapaxes(v, 1, 2)
    if k_valid is None:
        k_valid = jnp.ones((kk.shape[2],), jnp.int32)
    mode = _mode()
    if mode == "ref":
        out = R.flash_attention_ref(qk, kk, vk, q_pos, k_pos, k_valid,
                                    causal=causal, window=window,
                                    softcap=softcap)
    else:
        out = flash_attention_bhsd(qk, kk, vk, q_pos, k_pos, k_valid,
                                   causal=causal, window=window,
                                   softcap=softcap,
                                   interpret=(mode == "interpret"))
    return jnp.swapaxes(out, 1, 2).reshape(q.shape[0], q.shape[1], -1)


# ---------------------------------------------------------------------------
# fused matmul
# ---------------------------------------------------------------------------

def matmul_fused(x, w, bias=None, *, activation="none", out_dtype=None):
    mode = _mode()
    if mode == "ref":
        return R.matmul_fused_ref(x, w, bias, activation=activation,
                                  out_dtype=out_dtype)
    return _matmul_pallas(x, w, bias, activation=activation,
                          out_dtype=out_dtype,
                          interpret=(mode == "interpret"))


# ---------------------------------------------------------------------------
# one-pass norm
# ---------------------------------------------------------------------------

def norm_onepass(x, scale, bias=None, *, kind="rmsnorm", eps=1e-6):
    mode = _mode()
    if mode == "ref":
        return R.norm_onepass_ref(x, scale, bias, kind=kind, eps=eps)
    return _norm_pallas(x, scale, bias, kind=kind, eps=eps,
                        interpret=(mode == "interpret"))


# ---------------------------------------------------------------------------
# linear recurrence scan
# ---------------------------------------------------------------------------

def linear_scan(a, b, h0=None):
    """a, b: (N, S, F).  Returns all states (N, S, F)."""
    mode = _mode()
    if mode == "ref":
        return R.linear_scan_ref(a, b, h0)
    return _scan_pallas(a, b, h0, interpret=(mode == "interpret"))
