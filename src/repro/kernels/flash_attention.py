"""Flash attention Pallas TPU kernel (online softmax).

This is the TPU adaptation of SSR's "fine-grained pipeline for nonlinear
kernels": the paper's bypass line-buffer lets the Softmax reduction overlap
the MM that produces its input; on TPU the idiomatic equivalent is the
*online softmax* — running (m, l) statistics carried across KV blocks in
VMEM scratch so scores never round-trip to HBM and the softmax "second pass"
disappears into the QK^T/PV matmul pipeline on the MXU.

Layout: q (B, H, Sq, D); k, v (B, Hkv, Skv, D); positions as (1, S) int32
rows so masking (causal / sliding-window / ring-cache validity) is computed
from *absolute positions* inside the kernel, matching the ref oracle.

Grid: (B, H, Sq/bq, Skv/bk) — the KV dimension is sequential ("arbitrary")
and accumulates into (acc, m, l) VMEM scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.backend import compat

NEG_INF = -1e30


def _flash_kernel(qpos_ref, kpos_ref, kvalid_ref, q_ref, k_ref, v_ref,  # ins
                  o_ref,                                                # outs
                  acc_ref, m_ref, l_ref,                                # scratch
                  *, scale, causal, window, softcap, kv_blocks):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    qp = qpos_ref[0].astype(jnp.int32)               # (bq,)
    kp = kpos_ref[0].astype(jnp.int32)               # (bk,)
    rel = qp[:, None] - kp[None, :]
    ok = kvalid_ref[0][None, :] > 0
    if causal:
        ok &= rel >= 0
    if window > 0:
        ok &= rel < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq, 1)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                           # (bq, bk)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == kv_blocks - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0, ...] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret"))
def flash_attention_bhsd(q, k, v, q_pos, k_pos, k_valid, *, causal=True,
                         window=0, softcap=0.0, block_q=128, block_k=128,
                         interpret=False):
    """q: (B,H,Sq,D), k/v: (B,Hkv,Skv,D) — returns (B,H,Sq,D).

    q_pos: (Sq,) int32; k_pos: (Skv,) int32; k_valid: (Skv,) int32 (0/1)."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    groups = h // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, skv, bq, bk)
    kv_blocks = skv // bk
    scale = 1.0 / math.sqrt(d)

    qp = q_pos.reshape(1, sq).astype(jnp.int32)
    kp = k_pos.reshape(1, skv).astype(jnp.int32)
    kv = k_valid.reshape(1, skv).astype(jnp.int32)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, kv_blocks=kv_blocks)

    grid = (b, h, sq // bq, kv_blocks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq), lambda b_, h_, i, j: (0, i)),
            pl.BlockSpec((1, bk), lambda b_, h_, i, j: (0, j)),
            pl.BlockSpec((1, bk), lambda b_, h_, i, j: (0, j)),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, i, j, g=groups: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, i, j, g=groups: (b_, h_ // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            compat.vmem_scratch((bq, d), jnp.float32),
            compat.vmem_scratch((bq, 1), jnp.float32),
            compat.vmem_scratch((bq, 1), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qp, kp, kv, q, k, v)
