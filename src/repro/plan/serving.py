"""Runtime lowering of a ``ServingPlan`` for the continuous-batching engine.

``ExecutionPlan``s (PR 3) run synthetic pipelined forwards; this module is
what lets the *serving engine* consume them under live traffic, realizing
the paper's two regimes from one searched artifact:

  * **chunked prefill as plan stages** — an admitted prompt is sliced into
    ``chunk``-token chunks that stream through the plan's (uneven) stage
    slices as microbatches.  ``PrefillPipeline`` advances every in-flight
    chunk by at most ONE stage per engine tick (classic pipeline occupancy:
    one chunk per stage per tick, chunks of one prompt one stage apart), so
    a long prompt never stalls decode — prefill wants spatial pipelining.
  * **spatial decode replicas** — the plan's spatial width
    (``n_microbatches``) becomes N independent slot-partitioned decode
    engines; each runs ``make_plan_decode_step``, a per-slot batched decode
    that walks the stage slices in order, threading hidden states between
    stages and updating each stage's group-range of the replica's cache —
    decode wants replicated low-latency engines.

Numerical contract (enforced by ``tests/test_serving_parity.py``): token
streams through any ServingPlan are identical to isolated one-shot decode.
Three facts make that hold: (1) slicing the group scan into consecutive
stage sub-scans executes the same per-group ops in the same order; (2) a
chunk's first pass (``cont=False``) uses the exact one-shot prefill branch,
and continuation chunks attend the position-ordered cache (zeros
interspersed, valid keys in one-shot order — see
``layers.multi_head_attention(attend_cache=True)``); (3) recurrent/SSM
state threads between chunks exactly (a split scan is the same scan).
Chunking auto-disables where exactness cannot hold: MoE FFNs (capacity is
computed per call, so chunk-local routing would differ from one-shot) and
sliding-window prompts longer than the ring (wrap order differs) fall back
to a single whole-prompt chunk — still walked stage by stage.

Multi-device sharing: ``place_params`` puts the stacked params on a
``launch.mesh.make_plan_mesh`` so all replicas read the same stage-sharded
copy; on a single host device it is a no-op passthrough.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.backend import compat
from repro.models import transformer as T
from repro.pipeline.executor import run_stage
from repro.plan.ir import ExecutionPlan, ServingPlan
# the embed / final-norm+head / stage-slice helpers are shared with the
# measured-validation path so the numerical parity contract has exactly
# one implementation per term
from repro.plan.validate import _embed, _finish, _stage_slice


# ---------------------------------------------------------------------------
# jitted building blocks
# ---------------------------------------------------------------------------

def make_chunk_embed(model) -> Callable:
    """embed(params, tokens (1, L)) -> hidden (1, L, d) — stage-0 input."""

    def f(params, tokens):
        return _embed(model, params, {"tokens": tokens})
    return jax.jit(f)


def make_stage_prefill(model, plan: ExecutionPlan, s: int,
                       cont: bool) -> Callable:
    """One prefill stage-step: run stage ``s``'s group slice over a chunk
    of hidden states against the request's (batch-1) cache, updating only
    that stage's group range.

    cont=False is the chunk-0 pass (the exact one-shot prefill branch);
    cont=True is a continuation chunk (fresh tokens additionally attend
    the ``pos_base`` tokens already in the cache).
    """
    cfg = model.cfg
    st = plan.stages[s]

    def f(params, part_cache, hidden, pos_base):
        stage_params = _stage_slice(params["stack"], plan, s)
        cache_sl = T.slice_cache_groups(part_cache, st.first_group,
                                        st.n_groups)
        y, new_sl, _ = run_stage(
            cfg, stage_params, hidden, cache=cache_sl, cache_index=pos_base,
            collect_state=True, attend_cache=cont)
        return y, T.merge_cache_groups(part_cache, new_sl, st.first_group)
    # the caller rebinds its part_cache to the output every stage-step,
    # so the input cache is donated (in-place update where supported)
    return compat.donating_jit(f, donate_argnums=(1,))


def make_stage_prefill_paged(model, plan: ExecutionPlan, s: int,
                             cont: bool) -> Callable:
    """One *paged* prefill stage-step: the chunk's global-attention K/V
    streams straight into the REPLICA's block pool — fresh pages are
    written through ``write_tables`` (shared warm-prefix blocks carry the
    sentinel, so their writes drop) and every query attends the full
    mapped prefix (warm blocks + earlier chunks) through
    ``block_tables``.  Only the non-paged leaves (SSM state, local-window
    rings) update in the request's batch-1 ``part_cache``; there is no
    dense staging buffer and no commit-time page copy.

    ``pos_base`` counts reused warm-prefix tokens plus earlier chunks, so
    a warm suffix enters chunk 0 already offset past the shared pages.
    Returns (hidden, new_replica_cache, new_part_cache)."""
    cfg = model.cfg
    st = plan.stages[s]

    def f(params, replica_cache, part_cache, hidden, pos_base,
          block_tables, write_tables):
        stage_params = _stage_slice(params["stack"], plan, s)
        view = T.combine_prefill_parts(replica_cache, part_cache)
        cache_sl = T.slice_cache_groups(view, st.first_group, st.n_groups)
        y, new_sl, _ = run_stage(
            cfg, stage_params, hidden, cache=cache_sl, cache_index=pos_base,
            collect_state=True, attend_cache=cont,
            block_tables=block_tables, write_tables=write_tables)
        new_view = T.merge_cache_groups(view, new_sl, st.first_group)
        new_paged, new_part = T.split_prefill_parts(new_view, replica_cache)
        return y, new_paged, new_part
    # both the replica pool and the request's dense part are rebound by
    # the caller each stage-step: donate both
    return compat.donating_jit(f, donate_argnums=(1, 2))


def make_prefill_finish(model) -> Callable:
    """finish(params, hidden (1, L, d)) -> (first_token (1,), logits):
    final norm + head at the chunk's last (exact-length) position."""

    def f(params, hidden):
        logits = _finish(model, params, hidden[:, -1:])
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, logits
    return jax.jit(f)


def make_plan_decode_step(model, plan: ExecutionPlan) -> Callable:
    """decode(params, cache, tokens (B, 1), positions (B,),
    block_tables=None) -> (next_tokens (B, 1), new_cache) — one batched
    greedy decode step for ONE replica, walking the plan's stage slices in
    order: hidden states thread between stages, each stage updates its own
    group range of the replica's slot cache.  Numerically identical to the
    monolithic ``serve_step`` (the group scan is merely sliced at stage
    boundaries).  ``block_tables`` addresses the replica's page pool when
    its cache is paged (``make_paged_cache`` — group axis still leads, so
    ``slice_cache_groups`` slices paged leaves unchanged).
    """
    cfg = model.cfg

    def step(params, cache, tokens, positions, block_tables=None):
        x = _embed(model, params, {"tokens": tokens})
        x = T.shard_act(x)
        new_slices = []
        for s, st in enumerate(plan.stages):
            stage_params = _stage_slice(params["stack"], plan, s)
            cache_sl = T.slice_cache_groups(cache, st.first_group,
                                            st.n_groups)
            x, new_sl, _ = run_stage(
                cfg, stage_params, x, cache=cache_sl, cache_index=positions,
                collect_state=True, block_tables=block_tables)
            new_slices.append(new_sl)
        new_cache = T.concat_cache_groups(new_slices)
        logits = _finish(model, params, x)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], new_cache
    return step


def make_plan_verify_step(model, plan: ExecutionPlan) -> Callable:
    """verify(params, cache, tokens (B, S), positions (B,),
    block_tables=None) -> (argmax_tokens (B, S), new_cache) — one
    speculative-verify step for ONE replica.  Identical stage walk to
    ``make_plan_decode_step`` except the S-token window per slot is
    scored at every position (the engine accepts the longest agreeing
    draft prefix and rolls the rest back)."""
    cfg = model.cfg

    def step(params, cache, tokens, positions, block_tables=None):
        x = _embed(model, params, {"tokens": tokens})
        x = T.shard_act(x)
        new_slices = []
        for s, st in enumerate(plan.stages):
            stage_params = _stage_slice(params["stack"], plan, s)
            cache_sl = T.slice_cache_groups(cache, st.first_group,
                                            st.n_groups)
            x, new_sl, _ = run_stage(
                cfg, stage_params, x, cache=cache_sl, cache_index=positions,
                collect_state=True, block_tables=block_tables)
            new_slices.append(new_sl)
        new_cache = T.concat_cache_groups(new_slices)
        logits = _finish(model, params, x)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache
    return step


def place_params(params, plan: ExecutionPlan, devices=None):
    """Share one stage-sharded copy of the params across all decode
    replicas: the stacked (group-axis-leading) leaves go onto a
    ``make_plan_mesh`` with the group axis split over 'stage' (uniform
    plans whose groups divide the stage count evenly; anything else — and
    single-device hosts — replicates).  Returns (params, mesh_or_None)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_plan_mesh

    devs = list(devices if devices is not None else jax.devices())
    if len(devs) < plan.n_stages or len(devs) == 1:
        return params, None
    mesh = make_plan_mesh(plan, devices=devs)
    S = plan.n_stages
    shard_groups = plan.is_uniform and plan.num_groups % S == 0

    def put(path_is_stack, leaf):
        if path_is_stack and shard_groups and leaf.shape[0] % S == 0:
            return jax.device_put(
                leaf, NamedSharding(mesh, P("stage")))
        return jax.device_put(leaf, NamedSharding(mesh, P()))

    out = dict(params)
    out["stack"] = jax.tree.map(lambda l: put(True, l), params["stack"])
    for k, v in params.items():
        if k != "stack":
            out[k] = jax.tree.map(lambda l: put(False, l), v)
    return out, mesh


# ---------------------------------------------------------------------------
# chunked-prefill pipeline (host-side scheduling)
# ---------------------------------------------------------------------------

@dataclass(eq=False)
class _Flight:
    ci: int                 # chunk index within its request
    si: int                 # next stage this chunk will execute
    hidden: Any             # (1, L, d) activations entering stage si
    pos_base: int           # tokens of this request already in the cache


@dataclass(eq=False)
class _PrefillItem:
    req: Any                # serving.Request
    slot: int               # global engine slot (reserved)
    replica: int
    local_slot: int
    chunks: List[np.ndarray]        # (1, L) token chunks, exact lengths
    part_cache: Any                 # batch-1 full-group cache being built
    #                                 (paged items: the dense remainder
    #                                 only — pool leaves live in the
    #                                 replica cache)
    next_chunk: int = 0
    flight: List[_Flight] = field(default_factory=list)
    final_hidden: Any = None
    reused: int = 0                 # warm-prefix tokens whose prefill is
    #                                 skipped (chunks cover the suffix)
    bt: Any = None                  # (1, max_blocks) gather table (paged)
    wt: Any = None                  # (1, max_blocks) fresh-write table
    rt: Any = None                  # the PlanRuntime this item was admitted
    #                                 under.  Drain-and-rebind: a re-plan
    #                                 (``ServingEngine.replan``) never
    #                                 re-slices an in-flight chunk's stage
    #                                 walk — its remaining chunks finish on
    #                                 the runtime that started them, while
    #                                 new admissions (and decode) bind the
    #                                 new plan.


class PlanRuntime:
    """Jitted callables + chunking policy for one (model, ServingPlan)."""

    def __init__(self, model, splan: ServingPlan, max_seq: int):
        cfg = model.cfg
        if cfg.family in ("audio", "vision", "vlm") or cfg.mrope_sections:
            raise NotImplementedError(
                "plan-driven serving covers token-LM families "
                "(dense/moe/hybrid/ssm)")
        self.model = model
        self.splan = splan
        self.max_seq = max_seq
        plan = splan.plan
        assert plan.num_groups == cfg.num_groups, (plan.num_groups,
                                                   cfg.num_groups)
        self.embed = make_chunk_embed(model)
        self.finish = make_prefill_finish(model)
        self.stage_fns = {
            (s, cont): make_stage_prefill(model, plan, s, cont)
            for s in range(plan.n_stages) for cont in (False, True)}
        self.stage_fns_paged = {
            (s, cont): make_stage_prefill_paged(model, plan, s, cont)
            for s in range(plan.n_stages) for cont in (False, True)}
        # the engine rebinds its replica cache to each step's output, so
        # the input cache buffers are donated (dead on return)
        self.decode_step = compat.donating_jit(
            make_plan_decode_step(model, plan), donate_argnums=(1,))
        self.verify_step = compat.donating_jit(
            make_plan_verify_step(model, plan), donate_argnums=(1,))
        # chunking exactness gates (mirrors the engine's bucketing gates):
        # MoE capacity is per-call, so chunk-local routing would diverge
        # from the one-shot prefill; a prompt that wraps a sliding-window
        # ring must wrap it in one shot exactly as the gold prefill does.
        self._moe = any(b.ffn == "moe" for b in cfg.block_pattern)
        self._ring_min = min(
            (min(max_seq, cfg.window_size)
             for b in cfg.block_pattern if b.mixer == "attn_local"),
            default=0)

    def split_chunks(self, prompt: np.ndarray) -> List[np.ndarray]:
        plen = len(prompt)
        c = self.splan.chunk
        if self._moe or (self._ring_min and plen > self._ring_min) \
                or plen <= c:
            cuts = [plen]
        else:
            cuts = [c] * (plen // c)
            if plen % c:
                cuts.append(plen % c)
        out, a = [], 0
        for n in cuts:
            out.append(np.asarray(prompt[a:a + n], np.int32)[None])
            a += n
        return out


class PrefillPipeline:
    """In-flight chunked prefills, advanced one stage-step per tick.

    Occupancy rule: at most one chunk executes on each stage per tick
    (stages are spatially distinct accelerators), and chunks of the same
    request stay in order (a chunk never enters a stage before its
    predecessor has left it — its stage-range cache writes must land
    first).  ``step`` returns the items that finished this tick."""

    def __init__(self, runtime: PlanRuntime, params, tracer=None):
        self.rt = runtime
        self.params = params
        self.items: List[_PrefillItem] = []
        self.tracer = tracer            # repro.obs.Tracer or None (no-op)
        self.last_stages_run: frozenset = frozenset()  # stage idxs that
        #                                 executed a chunk last step() —
        #                                 the engine's per-stage occupancy
        #                                 accounting reads this

    @property
    def busy(self) -> bool:
        return bool(self.items)

    def admit(self, req, slot: int, replica: int, local_slot: int,
              reused: int = 0, tables=None):
        """Queue a chunked prefill.  Paged admissions pass the pager's
        ``tables=(block_table, write_table)``: chunks then stream their
        K/V straight into the replica's pool pages, the ``part_cache``
        holds only the dense remainder, and ``reused`` warm-prefix tokens
        are skipped outright (the chunks cover just the suffix)."""
        chunks = self.rt.split_chunks(req.prompt[reused:])
        if tables is not None:
            part_cache = T.make_prefill_part(self.rt.model.cfg,
                                             self.rt.max_seq)
            bt = jnp.asarray(tables[0])[None]
            wt = jnp.asarray(tables[1])[None]
        else:
            part_cache = self.rt.model.init_cache(1, self.rt.max_seq)
            bt = wt = None
        self.items.append(_PrefillItem(
            req=req, slot=slot, replica=replica, local_slot=local_slot,
            chunks=chunks, part_cache=part_cache, reused=reused,
            bt=bt, wt=wt, rt=self.rt))

    def adopt(self, items: List[_PrefillItem]):
        """Transplant in-flight items from a prior pipeline (re-plan
        drain-and-rebind).  Each item keeps its own ``rt``: its remaining
        chunks walk the stage slices it was admitted under — only the
        replica-cache routing (``item.replica``) is remapped by the
        engine before adoption."""
        self.items.extend(items)

    def _run_stage(self, it: _PrefillItem, si: int, cont: bool, hidden,
                   pos_base: int, caches, ci: int = 0):
        """Execute one stage for one chunk, routing paged items through
        the replica-cache-threading stage fns."""
        rt = it.rt or self.rt
        tr = self.tracer
        if tr is not None:
            t0 = time.perf_counter()
            ntok = int(hidden.shape[1])
        if it.bt is not None:
            fn = rt.stage_fns_paged[(si, cont)]
            hidden, new_cache, it.part_cache = fn(
                self.params, caches[it.replica], it.part_cache, hidden,
                jnp.int32(pos_base), it.bt, it.wt)
            caches[it.replica] = new_cache
            # every replica cache fronts ONE shared physical pool; the
            # stage step consumed (donated) the pool buffers through this
            # replica's view, so re-alias the fresh pool leaves into the
            # other replicas' views before anything else touches them
            if len(caches) > 1:
                for r in range(len(caches)):
                    if r != it.replica:
                        caches[r] = T.rebind_pool_leaves(caches[r],
                                                         new_cache)
        else:
            fn = rt.stage_fns[(si, cont)]
            hidden, it.part_cache = fn(
                self.params, it.part_cache, hidden, jnp.int32(pos_base))
        if tr is not None:
            tr.span(("stage", si), "prefill_chunk", t0, args={
                "uid": int(getattr(it.req, "uid", -1)), "slot": it.slot,
                "replica": it.replica, "chunk": ci, "tokens": ntok,
                "cont": bool(cont)})
        return hidden

    def _chunk_exited(self, it: _PrefillItem, fl: _Flight, finished,
                      on_chunk):
        """A chunk just left the last stage: its pool pages are written —
        let the engine publish the completed blocks (incremental compute
        cache) and collect the item if it was the final chunk."""
        if it.bt is not None and on_chunk is not None:
            done = it.reused + sum(c.shape[1]
                                   for c in it.chunks[:fl.ci + 1])
            on_chunk(it.slot, done)
        if fl.ci == len(it.chunks) - 1:
            it.final_hidden = fl.hidden
            finished.append(it)

    def step(self, caches=None, on_chunk=None) -> List[_PrefillItem]:
        """Advance every in-flight chunk by at most one stage; inject the
        next chunk of each item into stage 0 when it is free.

        caches: the engine's per-replica cache list — REQUIRED when paged
        items are in flight (their stage steps rebind
        ``caches[replica]``); on_chunk(slot, tokens_done) fires each time
        a paged chunk clears the last stage."""
        # per-item stage counts: after a re-plan, drained items still walk
        # the (possibly deeper/shallower) stage ladder they started on
        def n_stages(it):
            return (it.rt or self.rt).splan.n_stages

        occupied = set()
        finished: List[_PrefillItem] = []

        # advance existing flights, deepest stage first (so a vacated
        # stage is NOT re-entered in the same tick); ties (same stage)
        # resolve to the earlier chunk / earlier item — FIFO fairness.
        work = [(it, fl) for it in self.items for fl in it.flight]
        work.sort(key=lambda w: (-w[1].si, w[1].ci))
        for it, fl in work:
            if fl.si in occupied:
                continue
            occupied.add(fl.si)
            fl.hidden = self._run_stage(
                it, fl.si, fl.ci > 0 or it.reused > 0, fl.hidden,
                fl.pos_base, caches, ci=fl.ci)
            fl.si += 1
            if fl.si == n_stages(it):
                it.flight.remove(fl)
                self._chunk_exited(it, fl, finished, on_chunk)

        # inject next chunks at stage 0 when it is free this tick (a
        # predecessor chunk has always left stage 0 already: injection
        # executes stage 0 inline, so no flight ever sits at si == 0)
        for it in self.items:
            if it.next_chunk >= len(it.chunks) or 0 in occupied:
                continue
            occupied.add(0)
            tokens = it.chunks[it.next_chunk]
            pos_base = it.reused + sum(c.shape[1]
                                       for c in it.chunks[:it.next_chunk])
            hidden = self.rt.embed(self.params, jnp.asarray(tokens))
            hidden = self._run_stage(
                it, 0, it.next_chunk > 0 or it.reused > 0, hidden,
                pos_base, caches, ci=it.next_chunk)
            fl = _Flight(ci=it.next_chunk, si=1, hidden=hidden,
                         pos_base=pos_base)
            it.next_chunk += 1
            if fl.si == n_stages(it):
                self._chunk_exited(it, fl, finished, on_chunk)
            else:
                it.flight.append(fl)

        for it in finished:
            self.items.remove(it)
        self.last_stages_run = frozenset(occupied)
        return finished
