"""ExecutionPlan IR — the lowered, runnable form of an SSR design point.

The DSE side of the repo (``core/ea.py`` + ``core/customize.py``) searches
``Assignment``s: node→acc maps with per-acc chip counts and (dp, tp)
factorizations, where layer cuts need not be equal and acc widths need not
match.  The execution side (``pipeline/executor.py``) runs a stage-axis
shard_map over the scanned layer stack.  ``ExecutionPlan`` is the contract
between them:

  * ``stages``          — ordered stage slices over the model's *group*
                          axis (a group = one repetition of the config's
                          block_pattern, the finest runtime partition the
                          scanned stack supports).  Slices are contiguous
                          but need NOT be equal: the executor pads every
                          stage's parameter stack to ``max_groups`` entries
                          and masks the dead ones.
  * per-stage (dp, tp)  — the realized intra-stage sharding intent, scaled
                          from the DSE-requested submesh onto the uniform
                          mesh slot width (a rectangular device mesh cannot
                          give stages different widths, so narrow stages
                          are replicate-padded and the waste is recorded
                          for the cost model to charge).
  * ``n_microbatches``  — spatial dimension: microbatches in flight
                          through the stage pipeline per round.
  * ``n_rounds``        — sequential dimension: rounds of the spatial
                          pipeline (the paper's n_batches); the executor
                          streams ``n_rounds * n_microbatches`` microbatches
                          back-to-back, which is schedule-equivalent for a
                          linear pipeline.

Plans are pure data (hashable, jax-free numerics via numpy) so they can be
built inside DSE loops, logged, and diffed.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class StagePlan:
    """One pipeline stage: a contiguous group slice on a mesh slot."""
    index: int              # stage position in the pipeline (0-based)
    acc_id: int             # DSE accelerator this stage realizes
    first_group: int        # first model group owned by this stage
    n_groups: int           # number of groups (>= 1; stages may differ)
    dp: int = 1             # realized data-parallel degree inside the slot
    tp: int = 1             # realized tensor-parallel degree inside the slot
    width: int = 1          # devices in this stage's mesh slot (= dp * tp)
    requested_chips: int = 0   # submesh size the DSE asked for (0 = n/a)
    replica_waste: float = 0.0  # fraction of slot devices beyond the
    #                             work-proportional ideal (replicate-padding)

    def __post_init__(self):
        assert self.n_groups >= 1, self
        assert self.dp * self.tp == self.width, self

    @property
    def groups(self) -> Tuple[int, ...]:
        return tuple(range(self.first_group, self.first_group + self.n_groups))


@dataclass(frozen=True)
class ExecutionPlan:
    """A runnable spatial-sequential pipeline over the scanned layer stack."""
    stages: Tuple[StagePlan, ...]
    num_groups: int         # total model groups (sum of stage n_groups)
    n_microbatches: int     # microbatches in flight per round (spatial)
    n_rounds: int = 1       # rounds of the spatial pipeline (sequential)

    def __post_init__(self):
        assert self.stages, "plan needs >= 1 stage"
        assert self.n_microbatches >= 1 and self.n_rounds >= 1, self
        nxt = 0
        for s in self.stages:
            assert s.first_group == nxt, (
                f"stage {s.index} starts at group {s.first_group}, "
                f"expected {nxt} (stages must tile the group axis)")
            nxt += s.n_groups
        assert nxt == self.num_groups, (nxt, self.num_groups)

    # ----------------------------------------------------------- derived
    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def max_groups(self) -> int:
        """Padded per-stage stack depth: every stage's parameter stack is
        padded to this many groups; dead entries are masked at runtime."""
        return max(s.n_groups for s in self.stages)

    @property
    def total_microbatches(self) -> int:
        """Microbatches streamed end-to-end (spatial x sequential)."""
        return self.n_microbatches * self.n_rounds

    @property
    def stage_width(self) -> int:
        """Uniform mesh slot width (max over stages; narrow stages are
        replicate-padded up to it)."""
        return max(s.width for s in self.stages)

    @property
    def is_uniform(self) -> bool:
        return all(s.n_groups == self.stages[0].n_groups
                   for s in self.stages)

    @property
    def padding_waste(self) -> float:
        """Mean replicate-padding waste across stages (0 = the DSE chip
        split was already uniform)."""
        return float(np.mean([s.replica_waste for s in self.stages]))

    # ------------------------------------------------- executor interfaces
    def group_index_matrix(self) -> np.ndarray:
        """(n_stages, max_groups) int32 of *global* group indices: row s is
        stage s's groups, right-padded by repeating its last real group (a
        clamped gather keeps padded params finite, so masked-out compute
        cannot produce NaNs that would poison a select)."""
        G = self.max_groups
        out = np.zeros((self.n_stages, G), np.int32)
        for s in self.stages:
            for j in range(G):
                out[s.index, j] = s.first_group + min(j, s.n_groups - 1)
        return out

    def group_mask_matrix(self) -> np.ndarray:
        """(n_stages, max_groups) float32; 1.0 for live groups, 0.0 for
        padded entries (dead groups pass activations through unchanged)."""
        G = self.max_groups
        out = np.zeros((self.n_stages, G), np.float32)
        for s in self.stages:
            out[s.index, :s.n_groups] = 1.0
        return out

    def stage_of_group(self, g: int) -> int:
        for s in self.stages:
            if s.first_group <= g < s.first_group + s.n_groups:
                return s.index
        raise IndexError(g)

    def mesh_factors(self, width: Optional[int] = None) -> Tuple[int, int]:
        """(data, model) axis sizes for a rectangular (stage, data, model)
        mesh of slot width ``width``: the model axis is the largest tp all
        stages share (gcd), the data axis absorbs the rest."""
        w = width or self.stage_width
        m = w
        for s in self.stages:
            m = math.gcd(m, max(s.tp, 1))
        m = max(m, 1)
        return w // m, m

    # ------------------------------------------------------------- display
    def describe(self) -> str:
        lines = [f"ExecutionPlan: {self.n_stages} stages x "
                 f"{self.n_microbatches} microbatches x "
                 f"{self.n_rounds} rounds "
                 f"(groups={self.num_groups}, padded depth={self.max_groups},"
                 f" waste={self.padding_waste:.2f})"]
        for s in self.stages:
            lines.append(
                f"  stage {s.index}: groups [{s.first_group}.."
                f"{s.first_group + s.n_groups - 1}] acc{s.acc_id} "
                f"dp{s.dp}xtp{s.tp} width={s.width} "
                f"(requested {s.requested_chips} chips, "
                f"waste={s.replica_waste:.2f})")
        return "\n".join(lines)


@dataclass(frozen=True)
class ServingPlan:
    """An ``ExecutionPlan`` lowered for the continuous-batching engine.

    The plan's two pipeline dimensions map onto the two serving regimes
    (the SSR latency-throughput tradeoff under live traffic):

      * ``plan.stages``          — the *prefill pipeline*: admitted prompts
                                   are sliced into ``chunk``-token chunks
                                   that stream through the stage slices as
                                   microbatches, one stage-step per engine
                                   tick, interleaved with decode;
      * ``plan.n_microbatches``  — the *spatial width* becomes the number
                                   of independent decode replicas; the
                                   engine's ``slots`` are partitioned over
                                   them (``replica_slots``) and each
                                   replica runs a batched per-slot decode
                                   walk over the same stage slices.

    Pure data (like ``ExecutionPlan``); the runtime lowering lives in
    ``repro.plan.serving``.
    """
    plan: ExecutionPlan
    slots: int                        # engine slots, total over replicas
    chunk: int                        # prefill chunk length (tokens)
    replica_slots: Tuple[int, ...]    # per-replica slot counts (sum = slots)

    def __post_init__(self):
        assert self.slots >= 1 and self.chunk >= 1, self
        assert sum(self.replica_slots) == self.slots, self
        assert all(n >= 1 for n in self.replica_slots), self

    @property
    def n_replicas(self) -> int:
        return len(self.replica_slots)

    @property
    def n_stages(self) -> int:
        return self.plan.n_stages

    @property
    def label(self) -> str:
        """Compact design-point tag ("3s x 2r c16") for controller logs,
        bench rows, and the serve CLI; the monolithic point (no plan) is
        conventionally labelled "mono"."""
        return f"{self.n_stages}s x {self.n_replicas}r c{self.chunk}"

    def replica_of_slot(self, slot: int) -> Tuple[int, int]:
        """Global slot id -> (replica index, slot index inside it)."""
        start = 0
        for r, n in enumerate(self.replica_slots):
            if slot < start + n:
                return r, slot - start
            start += n
        raise IndexError(slot)

    def replica_range(self, r: int) -> Tuple[int, int]:
        start = sum(self.replica_slots[:r])
        return start, start + self.replica_slots[r]

    def describe(self) -> str:
        return (f"ServingPlan: {self.n_replicas} decode replicas over "
                f"{self.slots} slots {list(self.replica_slots)}, "
                f"chunked prefill (chunk={self.chunk}) through "
                f"{self.n_stages} stages\n" + self.plan.describe())


def uniform_plan(num_groups: int, n_stages: int, n_microbatches: int, *,
                 n_rounds: int = 1, dp: int = 1, tp: int = 1
                 ) -> ExecutionPlan:
    """The legacy executor's contract as a plan: equal contiguous stage
    slices, one shared (dp, tp).  Requires num_groups % n_stages == 0 —
    uneven splits come from ``plan.lower.lower``, not from here."""
    if n_stages < 1 or num_groups % n_stages:
        raise ValueError(
            f"uniform_plan: n_stages={n_stages} does not evenly divide "
            f"num_groups={num_groups}; pick a divisor of the group count "
            f"or lower an uneven Assignment via plan.lower.lower")
    per = num_groups // n_stages
    stages = tuple(
        StagePlan(index=i, acc_id=i, first_group=i * per, n_groups=per,
                  dp=dp, tp=tp, width=dp * tp, requested_chips=dp * tp)
        for i in range(n_stages))
    return ExecutionPlan(stages=stages, num_groups=num_groups,
                         n_microbatches=n_microbatches, n_rounds=n_rounds)


def _divisor_pairs(w: int) -> Sequence[Tuple[int, int]]:
    return [(d, w // d) for d in range(1, w + 1) if w % d == 0]


def fit_dp_tp(width: int, want_dp: int, want_tp: int,
              max_dp: Optional[int] = None) -> Tuple[int, int]:
    """Realize a requested (dp, tp) on a slot of ``width`` devices: the
    divisor pair of ``width`` closest (log-ratio) to the requested
    factorization, with dp optionally capped (dp cannot exceed the
    per-microbatch batch)."""
    want = math.log(max(want_dp, 1) / max(want_tp, 1))
    best, best_d = None, math.inf
    for dp, tp in _divisor_pairs(width):
        if max_dp is not None and dp > max(max_dp, 1):
            continue
        d = abs(math.log(dp / tp) - want)
        if d < best_d:
            best, best_d = (dp, tp), d
    return best if best is not None else (1, width)
