"""Measured-vs-predicted validation of ``ExecutionPlan``s.

The analytic side of the repo prices a design point with the SSR cost
model (``core/costmodel.py`` + ``core/assignment.simulate``); this module
closes the loop by *executing* the plan on whatever backend is available
(CPU / Pallas-interpret / TPU — every kernel routes through
``repro.backend.dispatch``) and timing the real per-stage work:

  * ``stage_forward``    — one stage's group slice as a standalone jittable
                           function (exactly the computation a stage's
                           submesh runs per microbatch tick);
  * ``check_roundtrip``  — chain the stage slices and compare against the
                           non-pipelined reference forward (lowering must
                           be numerically lossless);
  * ``measure_plan``     — time each stage on a microbatch, compose the
                           measured stage times through the pipeline
                           schedule (M microbatches through S stages take
                           sum(t_s) + (M-1)*max(t_s)), and report measured
                           latency/throughput next to the analytic
                           prediction;
  * ``predict_plan``     — the analytic prediction for the *realized* plan
                           (uniform slot widths, re-fit dp/tp), i.e. what
                           the cost model says after being charged the
                           replicate-padding waste;
  * ``measured_design_points`` — the measured points as
                           ``core.pareto.DesignPoint``s tagged
                           ``source="measured"`` so they sit on the same
                           Pareto axes as the analytic sweep.

Per-stage timing runs the slices sequentially on the local device(s), so
it works on a 1-device CPU host; the full multi-device shard_map execution
path is ``pipeline.plan_forward`` (exercised by the distributed tests).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.assignment import simulate
from repro.core.costmodel import Features, stage_time
from repro.core.graph import Graph
from repro.core.hw import Chip, TPU_V5E
from repro.core.pareto import DesignPoint
from repro.plan.ir import ExecutionPlan
from repro.plan.lower import realized_assignment


def _backend_name() -> str:
    from repro.backend import compat
    return compat.backend()


def _stage_slice(stack_params, plan: ExecutionPlan, s: int):
    st = plan.stages[s]
    return jax.tree.map(
        lambda x: x[st.first_group:st.first_group + st.n_groups],
        stack_params)


def stage_forward(model, params, x, plan: ExecutionPlan, s: int):
    """Apply stage ``s``'s (unpadded) group slice to hidden states ``x``."""
    from repro.models import transformer as T
    sl = _stage_slice(params["stack"], plan, s)
    y, _, _ = T.run_stack(sl, x, model.cfg)
    return y


def _embed(model, params, batch):
    from repro.models import layers as L
    cfg = model.cfg
    if "embeds" in batch:
        return batch["embeds"].astype(cfg.dtype)
    return L.embed(params["embed"], batch["tokens"], cfg).astype(cfg.dtype)


def _finish(model, params, y):
    from repro.models import layers as L
    cfg = model.cfg
    y = L.apply_norm(params["final_norm"], y, cfg)
    return L.logits_head(params.get("embed"), params.get("head"), y, cfg)


def check_roundtrip(model, params, batch, plan: ExecutionPlan) -> float:
    """Max abs error between the chained stage slices and the reference
    forward — the lowering-is-lossless invariant (device-count free)."""
    x = _embed(model, params, batch)
    y = x
    for s in range(plan.n_stages):
        y = stage_forward(model, params, y, plan, s)
    got = _finish(model, params, y)
    ref, _ = model.forward(params, batch)
    return float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - ref.astype(jnp.float32))))


def measure_plan(model, params, batch, plan: ExecutionPlan, *,
                 repeat: int = 3, check: bool = True) -> Dict:
    """Execute + time the plan's per-stage work on the local backend.

    Each stage is jitted over one microbatch of hidden states and timed;
    the embed rides stage 0 and the final-norm + head ride the last stage
    (mirroring ``realized_assignment``, so measured and analytic price the
    same graph).  The measured stage times are composed through the
    pipeline schedule:

      latency  (first microbatch) = sum(t_s)
      makespan (M_total batches)  = sum(t_s) + (M_total - 1) * max(t_s)

    Returns per-stage seconds, composed latency/makespan, and (optionally)
    the round-trip error vs the reference forward."""
    cfg = model.cfg
    x = _embed(model, params, batch)
    B, seq, d = x.shape
    M = plan.total_microbatches
    assert B % M == 0, (B, M)
    mb = B // M
    x_mb = x[:mb]

    def _timeit(fn, arg):
        jax.block_until_ready(fn(arg))            # compile outside the clock
        t0 = time.perf_counter()
        for _ in range(repeat):
            out = jax.block_until_ready(fn(arg))
        return (time.perf_counter() - t0) / repeat, out

    per_stage = []
    cur = x_mb
    for s in range(plan.n_stages):
        t, cur = _timeit(
            jax.jit(lambda h, s=s: stage_forward(model, params, h, plan, s)),
            cur)
        per_stage.append(t)
    mb_batch = jax.tree.map(lambda v: v[:mb], batch)
    t_embed, _ = _timeit(jax.jit(lambda b: _embed(model, params, b)),
                         mb_batch)
    t_head, _ = _timeit(jax.jit(lambda y: _finish(model, params, y)), cur)
    per_stage[0] += t_embed
    per_stage[-1] += t_head

    t_max = max(per_stage)
    latency = sum(per_stage)
    makespan = latency + (M - 1) * t_max
    res = {
        "per_stage_s": per_stage,
        "latency_s": latency,
        "makespan_s": makespan,
        "n_stages": plan.n_stages,
        "n_microbatches": M,
        "tokens_per_s": B * seq / makespan if makespan > 0 else 0.0,
        "backend": _backend_name(),
    }
    if check:
        res["max_abs_err"] = check_roundtrip(model, params, batch, plan)
    return res


def measure_serving_stage_times(model, params, splan, max_seq: int, *,
                                runtime=None, repeat: int = 3) -> Dict:
    """Measured wall seconds of one ServingPlan's serving-side jitted
    units, the inputs to the adaptive re-plan controller's windowed cost
    model (``serving.adaptive``):

      * ``stage_s[s]`` — one chunk-prefill stage-step of stage ``s``
        (batch 1, ``splan.chunk`` tokens), i.e. the per-tick cost the
        ``PrefillPipeline`` adds while a prompt is streaming;
      * ``decode_step_s[r]`` — one batched decode step of replica ``r``
        (batch = its slot-partition width).

    Compile time is excluded (one warmup call per fn).  Pass the engine's
    cached ``PlanRuntime`` as ``runtime`` to reuse its compiled stage fns;
    timing uses throwaway dense caches, never live engine state."""
    from repro.plan.serving import PlanRuntime   # local: serving imports us
    rt = runtime if runtime is not None else PlanRuntime(model, splan,
                                                         max_seq)

    def _timed(fn):
        # fn() must consume/produce its own donated state and return
        # something blockable
        jax.block_until_ready(fn())               # compile outside the clock
        t0 = time.perf_counter()
        for _ in range(repeat):
            out = jax.block_until_ready(fn())
        return (time.perf_counter() - t0) / repeat

    chunk = min(splan.chunk, max_seq)
    tokens = jnp.zeros((1, chunk), jnp.int32)
    hidden = rt.embed(params, tokens)
    stage_s = []
    state = {"part": model.init_cache(1, max_seq), "h": hidden}
    for s in range(splan.n_stages):
        fn = rt.stage_fns[(s, False)]
        h_in = state["h"]

        def run(fn=fn, h_in=h_in):
            h, state["part"] = fn(params, state["part"], h_in, jnp.int32(0))
            return h
        stage_s.append(_timed(run))
        state["h"] = run()

    decode_step_s = []
    per_width: Dict[int, float] = {}
    for n in splan.replica_slots:
        if n not in per_width:
            st = {"cache": model.init_cache(n, max_seq)}
            toks = jnp.zeros((n, 1), jnp.int32)
            pos = jnp.zeros((n,), jnp.int32)

            def run_dec():
                nxt, st["cache"] = rt.decode_step(params, st["cache"],
                                                  toks, pos)
                return nxt
            per_width[n] = _timed(run_dec)
        decode_step_s.append(per_width[n])
    return {
        "stage_s": stage_s,
        "decode_step_s": decode_step_s,
        "chunk": splan.chunk,
        "n_stages": splan.n_stages,
        "n_replicas": splan.n_replicas,
        "backend": _backend_name(),
    }


def predict_plan(plan: ExecutionPlan, graph: Graph, *, hw: Chip = TPU_V5E,
                 feats: Features = Features()) -> Dict:
    """Analytic prediction for the realized plan: the scheduler prices the
    uniform-width stages (replicate-padding charged — a stage that wanted
    fewer chips than the slot gains nothing; one that wanted more is
    starved) over M_total pipelined microbatches."""
    assign = realized_assignment(plan, graph)
    M = plan.total_microbatches
    r = simulate(graph, assign, M, hw=hw, feats=feats)
    per_stage = [
        stage_time([graph.nodes[i] for i in assign.nodes_of(s.index)],
                   assign.accs[s.index], graph, hw,
                   batch_frac=1.0 / M, feats=feats)
        for s in plan.stages]
    return {
        "per_stage_s": per_stage,
        "latency_s": r.latency,
        "makespan_s": r.makespan,
        "throughput_tops": r.throughput_tops(),
        "padding_waste": plan.padding_waste,
    }


def auto_spatial_width(build_plan, graph: Graph, *, n_rounds: int = 1,
                       measure_with=None, max_candidates: int = 6,
                       hw: Chip = TPU_V5E,
                       feats: Features = Features()) -> int:
    """Pick the plan's spatial width (``n_microbatches``) from per-stage
    times instead of requiring the caller to pass it.

    build_plan: callable M -> ExecutionPlan (the lowering parameterized by
    the spatial width — ``plan.lower.lower`` passes its stage builder).

    Candidates are the divisors of the effective batch (so the executor's
    ``B % (M * n_rounds) == 0`` contract always holds), subsampled to
    ``max_candidates``.  Each candidate is scored by the pipeline-composed
    makespan of its per-stage times: *measured* on the local backend when
    ``measure_with=(model, params, batch)`` is given, the analytic cost
    model otherwise.  The tradeoff is real in both: more microbatches
    shrink the bubble but re-pay the per-invocation cost (weight reads)
    once per microbatch.
    """
    B = max(graph.shape.global_batch, 1)
    if B % n_rounds:
        raise ValueError(
            f"auto_spatial_width: n_rounds={n_rounds} does not divide the "
            f"global batch {B}, so no spatial width can satisfy the "
            f"executor's B % (M * n_rounds) == 0 contract")
    eff = B // n_rounds
    cands = [d for d in range(1, eff + 1) if eff % d == 0]
    if len(cands) > max_candidates:
        # keep the extremes + an even spread between them
        idx = np.unique(np.linspace(0, len(cands) - 1,
                                    max_candidates).round().astype(int))
        cands = [cands[i] for i in idx]

    best_m, best_t = cands[0], float("inf")
    for M in cands:
        plan = build_plan(M)
        if measure_with is not None:
            model, params, batch = measure_with
            t = measure_plan(model, params, batch, plan,
                             repeat=1, check=False)["makespan_s"]
        else:
            t = predict_plan(plan, graph, hw=hw, feats=feats)["makespan_s"]
        if t < best_t:
            best_m, best_t = M, t
    return best_m


def measured_design_points(model, params, batch, graph: Graph,
                           plans: Sequence[ExecutionPlan], *,
                           repeat: int = 3) -> List[DesignPoint]:
    """One measured ``DesignPoint`` per plan (source="measured"), on the
    same axes as the analytic sweep: latency = composed makespan of the
    submitted workload, throughput = graph MM-TFLOP/s over it."""
    pts = []
    for plan in plans:
        m = measure_plan(model, params, batch, plan, repeat=repeat)
        thr = graph.total_mm_flops / m["makespan_s"] / 1e12 \
            if m["makespan_s"] > 0 else 0.0
        pts.append(DesignPoint(
            strategy="hybrid" if plan.n_stages > 1 else "sequential",
            n_acc=plan.n_stages, n_batches=plan.total_microbatches,
            latency=m["makespan_s"], throughput_tops=thr,
            detail=(f"measured on {m['backend']}; "
                    f"err={m.get('max_abs_err', float('nan')):.2e}"),
            source="measured"))
    return pts
