"""ExecutionPlan subsystem: lower searched SSR assignments to runnable
heterogeneous spatial-sequential pipelines (search -> plan -> execute)."""
from repro.plan.ir import (ExecutionPlan, StagePlan, fit_dp_tp,
                           uniform_plan)
from repro.plan.lower import group_acc_map, lower, realized_assignment
from repro.plan.validate import (check_roundtrip, measure_plan,
                                 measured_design_points, predict_plan,
                                 stage_forward)

__all__ = [
    "ExecutionPlan", "StagePlan", "uniform_plan", "fit_dp_tp",
    "lower", "group_acc_map", "realized_assignment",
    "check_roundtrip", "measure_plan", "measured_design_points",
    "predict_plan", "stage_forward",
]
