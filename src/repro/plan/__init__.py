"""ExecutionPlan subsystem: lower searched SSR assignments to runnable
heterogeneous spatial-sequential pipelines (search -> plan -> execute),
and lower plans for the serving engine (spatial decode replicas +
chunked prefill as plan stages — see ``repro.plan.serving``)."""
from repro.plan.ir import (ExecutionPlan, ServingPlan, StagePlan, fit_dp_tp,
                           uniform_plan)
from repro.plan.lower import (group_acc_map, lower, lower_serving,
                              realized_assignment, rereplicate_serving)
from repro.plan.validate import (auto_spatial_width, check_roundtrip,
                                 measure_plan, measure_serving_stage_times,
                                 measured_design_points, predict_plan,
                                 stage_forward)

__all__ = [
    "ExecutionPlan", "ServingPlan", "StagePlan", "uniform_plan",
    "fit_dp_tp", "lower", "lower_serving", "group_acc_map",
    "realized_assignment", "rereplicate_serving", "auto_spatial_width",
    "check_roundtrip", "measure_plan", "measure_serving_stage_times",
    "measured_design_points", "predict_plan", "stage_forward",
]
