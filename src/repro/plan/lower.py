"""Lowering pass: DSE ``Assignment`` -> runnable ``ExecutionPlan``.

The searched node→acc maps live on the *layer graph* (one node per block,
plus embed/head); the runnable stack executes *groups* (one repetition of
``cfg.block_pattern`` per scan step).  Lowering bridges the two:

  1. snap the node map to group boundaries (FLOPs-weighted majority vote
     per group — EA mutation can scatter single layers, and a stage cut
     inside a pattern period is not executable);
  2. merge consecutive same-acc groups into ordered pipeline stages
     (uneven slices allowed — the executor pads and masks);
  3. realize each acc's requested (chips, dp, tp) on the uniform mesh slot
     width ``devices // n_stages`` (a rectangular mesh cannot give stages
     different widths), recording the replicate-padding waste of stages
     that asked for less than the slot so the cost model can charge it.

Embed and head nodes ride with the first / last stage (the executor runs
them data-parallel outside the stage loop, exactly as the legacy pipeline
did).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.assignment import Assignment
from repro.core.costmodel import AccConfig
from repro.core.graph import Graph
from repro.plan.ir import ExecutionPlan, ServingPlan, StagePlan, fit_dp_tp


def _block_layers(graph: Graph) -> List[int]:
    """Indices of block nodes in layer order; validates the graph is the
    runnable block-granularity LM form (op-granularity and encoder-decoder
    graphs have no 1:1 node↔layer correspondence to the scanned stack)."""
    blocks = [n.idx for n in graph.nodes if n.kind == "block"]
    cfg = graph.cfg
    if len(blocks) != cfg.num_layers or cfg.family == "audio":
        raise ValueError(
            f"plan lowering needs a block-granularity LM graph "
            f"({len(blocks)} block nodes vs {cfg.num_layers} layers, "
            f"family={cfg.family!r}); build_graph(cfg, shape, "
            f"granularity='block')")
    return blocks


def group_acc_map(assign: Assignment, graph: Graph) -> List[int]:
    """Per-group acc id: FLOPs-weighted majority over the group's layers
    (first-seen acc wins ties, keeping the vote deterministic)."""
    cfg = graph.cfg
    period = len(cfg.block_pattern)
    votes: List[Dict[int, float]] = [dict() for _ in range(cfg.num_groups)]
    order: List[Dict[int, int]] = [dict() for _ in range(cfg.num_groups)]
    for li, node_idx in enumerate(_block_layers(graph)):
        g = li // period
        a = assign.acc_of[node_idx]
        node = graph.nodes[node_idx]
        votes[g][a] = votes[g].get(a, 0.0) + max(node.mm_flops, 1.0)
        order[g].setdefault(a, len(order[g]))
    return [max(v, key=lambda a: (v[a], -order[g][a]))
            for g, v in enumerate(votes)]


def _default_microbatches(graph: Graph, n_stages: int, n_rounds: int) -> int:
    """Just fill the pipeline — but the executor splits the batch into
    M * n_rounds microbatches, so M must satisfy B % (M * n_rounds) == 0:
    smallest such divisor >= n_stages (falling back to the largest one
    below it; 1 always qualifies when n_rounds divides B — else no M can
    make the plan executable and we keep M minimal for analytic use)."""
    B = max(graph.shape.global_batch, 1)
    eff = B // n_rounds if B % n_rounds == 0 else B
    divs = [d for d in range(1, eff + 1) if eff % d == 0]
    ge = [d for d in divs if d >= n_stages]
    return min(ge) if ge else max(d for d in divs if d <= n_stages)


def lower(assign: Assignment, graph: Graph,
          mesh_devices: Optional[int] = None, *,
          n_microbatches=None, n_rounds: int = 1,
          measure_with=None) -> ExecutionPlan:
    """Lower a searched ``Assignment`` to a runnable ``ExecutionPlan``.

    mesh_devices: device budget the plan will run on (defaults to the sum
    of requested acc chips — i.e. the DSE's own target platform).  The
    uniform mesh slot width is ``mesh_devices // n_stages``; per-stage
    (dp, tp) are re-fit onto that width, capped by the per-microbatch
    batch.  n_microbatches defaults to n_stages (just fills the pipeline);
    pass the string ``"auto"`` to pick the spatial width from per-stage
    times instead (``plan.validate.auto_spatial_width``: *measured* stage
    times when ``measure_with=(model, params, batch)`` is given, the
    analytic cost model otherwise).
    """
    cfg = graph.cfg
    acc_of_group = group_acc_map(assign, graph)

    # merge consecutive same-acc groups into stages (uneven allowed)
    runs: List[Tuple[int, int, int]] = []     # (acc_id, first_group, count)
    for g, a in enumerate(acc_of_group):
        if runs and runs[-1][0] == a:
            acc_id, first, cnt = runs[-1]
            runs[-1] = (acc_id, first, cnt + 1)
        else:
            runs.append((a, g, 1))
    n_stages = len(runs)
    total_req = sum(a.chips for a in assign.accs) or 1
    devices = mesh_devices or total_req
    width = max(devices // n_stages, 1)

    def build(M: int) -> ExecutionPlan:
        # dp cannot exceed the per-microbatch batch the executor carries
        mb = max(graph.shape.global_batch // max(M * n_rounds, 1), 1)
        stages = []
        for i, (acc_id, first, cnt) in enumerate(runs):
            acc: AccConfig = assign.accs[acc_id]
            dp, tp = fit_dp_tp(width, acc.dp, acc.tp, max_dp=mb)
            # work-proportional ideal share of the device budget vs the
            # uniform slot: the replicate-padding the mesh forces on us
            ideal = devices * acc.chips / total_req
            waste = max(0.0, (width - ideal) / width)
            stages.append(StagePlan(
                index=i, acc_id=acc_id, first_group=first, n_groups=cnt,
                dp=dp, tp=tp, width=width, requested_chips=acc.chips,
                replica_waste=waste))
        return ExecutionPlan(stages=tuple(stages),
                             num_groups=cfg.num_groups,
                             n_microbatches=M, n_rounds=n_rounds)

    if n_microbatches == "auto":
        from repro.plan.validate import auto_spatial_width
        M = auto_spatial_width(build, graph, n_rounds=n_rounds,
                               measure_with=measure_with)
    else:
        M = n_microbatches
        if M is None:
            M = _default_microbatches(graph, n_stages, n_rounds)
    return build(M)


def lower_serving(plan: ExecutionPlan, slots: int,
                  chunk: int = 16) -> ServingPlan:
    """Lower an ``ExecutionPlan`` for the continuous-batching engine.

    The plan's spatial width (``n_microbatches``) becomes the number of
    independent decode replicas; the engine's ``slots`` are partitioned
    over them as evenly as possible.  ``chunk`` is the prefill chunk
    length: admitted prompts stream through the plan's stages in
    ``chunk``-token microbatches, one stage-step per engine tick.
    """
    R = plan.n_microbatches
    if slots < R:
        raise ValueError(
            f"lower_serving: {slots} slots cannot feed {R} decode replicas "
            f"(the plan's spatial width n_microbatches={R}); give the "
            f"engine at least one slot per replica or lower a narrower "
            f"plan")
    if chunk < 1:
        raise ValueError(f"lower_serving: chunk={chunk} must be >= 1")
    base, rem = divmod(slots, R)
    replica_slots = tuple(base + (1 if r < rem else 0) for r in range(R))
    return ServingPlan(plan=plan, slots=slots, chunk=chunk,
                       replica_slots=replica_slots)


def rereplicate_serving(splan: ServingPlan, n_replicas: int, *,
                        chunk: Optional[int] = None) -> ServingPlan:
    """A new design point on the same stage slices with a different
    spatial decode width: re-lower ``splan`` with ``n_replicas`` replicas
    (the underlying ``ExecutionPlan``'s ``n_microbatches``), keeping the
    engine's slot count.  This is how the adaptive controller's candidate
    ladder is built from one searched plan — the stage cut is the searched
    artifact; the spatial width is the traffic-dependent knob."""
    import dataclasses
    if n_replicas < 1:
        raise ValueError(
            f"rereplicate_serving: n_replicas={n_replicas} must be >= 1")
    plan = dataclasses.replace(splan.plan, n_microbatches=n_replicas)
    return lower_serving(plan, splan.slots,
                         chunk=splan.chunk if chunk is None else chunk)


def realized_assignment(plan: ExecutionPlan, graph: Graph) -> Assignment:
    """Map a plan back onto the graph as an ``Assignment`` with the
    *realized* per-stage submeshes (uniform slot width, re-fit dp/tp) —
    this is what the analytic scheduler should price so predictions charge
    the replicate-padding the mesh forced (vs the DSE's requested split)."""
    cfg = graph.cfg
    period = len(cfg.block_pattern)
    blocks = _block_layers(graph)
    stage_of_node = {}
    for li, node_idx in enumerate(blocks):
        stage_of_node[node_idx] = plan.stage_of_group(li // period)
    acc_of = []
    for n in graph.nodes:
        if n.idx in stage_of_node:
            acc_of.append(stage_of_node[n.idx])
        elif n.kind == "embed":
            acc_of.append(0)
        else:                                   # head rides the last stage
            acc_of.append(plan.n_stages - 1)
    accs = tuple(AccConfig(chips=s.width, dp=s.dp, tp=s.tp)
                 for s in plan.stages)
    return Assignment(tuple(acc_of), accs)
