"""Derived observability: typed traffic snapshots and utilization views.

``TrafficSnapshot`` is the single typed observation the adaptive
controller reads each window (it used to be an ad-hoc dict built inside
the controller from engine internals).  ``utilization_from_trace``
recomputes time-based stage/replica utilization from the trace stream;
``fold_engine_metrics`` projects an engine ``stats()`` dict onto the
Prometheus registry as gauges.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

from .metrics import MetricsRegistry


@dataclass(frozen=True)
class TrafficSnapshot:
    """One observation window of serving traffic, as the controller sees it.

    lam: arrival rate (requests/s) over the rolling window.
    avg_prompt: mean prompt tokens of recent arrivals.
    avg_new: mean requested new tokens of recent arrivals.
    queued_tok: prompt tokens waiting in the admission queue.
    depth: forecast decode depth (active slots + expected arrivals
        over the controller horizon, capped at slot count).
    queue_len: requests waiting in the admission queue.
    active: currently active decode slots.
    violated: True when the rolling TTFT/TPOT percentiles breach SLOs.
    window_s: the rolling window the snapshot was computed over.
    """

    lam: float
    avg_prompt: float
    avg_new: float
    queued_tok: float
    depth: float
    queue_len: int
    active: int
    violated: bool
    window_s: float


def utilization_from_trace(tracer: Any) -> Dict[str, Any]:
    """Time-based stage/replica utilization from retained trace records.

    Walks span records: ``("stage", j)`` tracks accumulate prefill busy
    time, ``("replica", r)`` tracks accumulate decode/verify busy time.
    The window is the full [min t0, max t1] extent of retained records.
    """
    stage_busy: Dict[int, float] = {}
    replica_busy: Dict[int, float] = {}
    tmin: Optional[float] = None
    tmax: Optional[float] = None
    for rec in tracer.records():
        if rec[0] != "X":
            continue
        _, track, _name, t0, t1 = rec[:5]
        tmin = t0 if tmin is None else min(tmin, t0)
        tmax = t1 if tmax is None else max(tmax, t1)
        if isinstance(track, tuple):
            kind, idx = track
            if kind == "stage":
                stage_busy[idx] = stage_busy.get(idx, 0.0) + (t1 - t0)
            elif kind == "replica":
                replica_busy[idx] = replica_busy.get(idx, 0.0) + (t1 - t0)
    window = (tmax - tmin) if (tmin is not None and tmax is not None) else 0.0
    out: Dict[str, Any] = {
        "window_s": window,
        "stage_busy_s": dict(sorted(stage_busy.items())),
        "replica_busy_s": dict(sorted(replica_busy.items())),
    }
    if window > 0:
        out["stage_busy_frac"] = {s: b / window for s, b in sorted(stage_busy.items())}
        out["replica_busy_frac"] = {r: b / window for r, b in sorted(replica_busy.items())}
    else:
        out["stage_busy_frac"] = {}
        out["replica_busy_frac"] = {}
    return out


def fold_engine_metrics(reg: MetricsRegistry, st: Dict[str, Any]) -> None:
    """Project an engine ``stats()`` dict onto registry gauges.

    Gauges are *set* (not accrued), so folding the same snapshot twice
    is idempotent — repeated exports in one window agree.
    """
    g = reg.gauge
    g("repro_throughput_tok_s", "generated tokens per second (window)").set(
        st.get("throughput_tok_s", 0.0))
    g("repro_slot_occupancy", "mean active-slot fraction per tick").set(
        st.get("slot_occupancy", 0.0))
    g("repro_tokens_per_step", "mean tokens committed per decode step").set(
        st.get("tokens_per_step", 0.0))
    g("repro_replans_total", "plan swaps this window").set(st.get("replans", 0))
    g("repro_migrations_total", "slot migrations this window").set(
        st.get("migrations", 0))
    g("repro_migration_copies_total", "KV copies during migration (0 = zero-copy)").set(
        st.get("migration_copies", 0))
    g("repro_ticks_total", "engine ticks this window").set(st.get("ticks", 0))
    for phase, secs in st.get("phase_time_s", {}).items():
        g("repro_phase_seconds", "host wall seconds per engine phase (window)",
          phase=phase).set(secs)
    util = st.get("utilization", {})
    for s, frac in util.get("stage_bubble_frac", {}).items():
        g("repro_stage_bubble_frac",
          "fraction of busy-pipeline ticks each prefill stage sat idle",
          stage=str(s)).set(frac)
    for r, occ in util.get("replica_occupancy", {}).items():
        g("repro_replica_occupancy",
          "mean occupied-slot fraction per decode replica",
          replica=str(r)).set(occ)
    g("repro_replica_load_spread",
      "max-min replica occupancy gap (0 = balanced)").set(
        util.get("replica_load_spread", 0.0))
    g("repro_spec_acceptance_rate", "accepted / proposed draft tokens").set(
        util.get("spec_acceptance_rate", 0.0))
    g("repro_prefix_hit_rate", "warm-prefix admissions / total admissions").set(
        util.get("prefix_hit_rate", 0.0))
    cache = st.get("cache")
    if cache:
        g("repro_cache_blocks_in_use", "paged KV blocks currently allocated").set(
            cache.get("blocks_in_use", 0))
        g("repro_cache_peak_blocks", "peak concurrent paged KV blocks").set(
            cache.get("peak_blocks_in_use", 0))
        g("repro_kv_capacity_x", "effective KV capacity multiplier (int8)").set(
            cache.get("kv_capacity_x", 1.0))
