"""Low-overhead engine tracer: ring-buffered, monotonic-clock records.

The tracer stores *complete* records (spans carry both endpoints) in a
fixed-size ring, so exporting can never produce a ``B`` without its
matching ``E`` even after the ring wraps.  All timestamps come from
``time.perf_counter()`` — the same monotonic clock the serving engine
uses for ``phase_time_s`` and request latencies, so trace spans line up
with engine stats by construction.

Record shapes (plain tuples, newest-kept ring):

- ``("X", track, name, t0, t1, args, flow_out, flow_in)`` — a span.
- ``("I", track, name, t, args)`` — an instant event.
- ``("C", track, name, t, values)`` — a counter sample (dict of series).
- ``("F", track, phase, fid, t)`` — a bare flow endpoint (``"s"``/``"f"``).

``track`` is either a string (``"tick"``, ``"requests"``) or a tuple
(``("stage", j)``, ``("replica", r)``); the Perfetto exporter maps each
track to its own named thread so stages and replicas render as parallel
timelines.

The hot path contract: when tracing is disabled the engine holds
``self._tr is None`` and every emission site is guarded, so *zero*
records are created.  ``RECORDS_TOTAL`` below counts every record ever
pushed by any tracer in the process — tests use it as an allocation
probe to pin the no-op fast path.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple, Union

Track = Union[str, Tuple[str, int]]

# Process-wide record counter.  Incremented on every record pushed into
# any Tracer; an engine running with trace=None must leave it untouched
# (asserted by tests/test_obs.py).
RECORDS_TOTAL = 0


@dataclass(frozen=True)
class TraceConfig:
    """Configuration for engine tracing.

    capacity: ring size in records; oldest records are dropped once the
        ring wraps (``Tracer.dropped`` counts them).
    path: optional output path — callers (CLI, benchmarks) write the
        Perfetto JSON here when the run finishes.
    """

    capacity: int = 1 << 16
    path: Optional[str] = None


class Tracer:
    """Ring-buffered trace-event recorder (monotonic clock)."""

    __slots__ = ("capacity", "_buf", "_idx", "t0")

    def __init__(self, capacity: int = 1 << 16):
        if capacity <= 0:
            raise ValueError("tracer capacity must be positive")
        self.capacity = int(capacity)
        self._buf: List[Optional[tuple]] = [None] * self.capacity
        self._idx = 0
        self.t0 = time.perf_counter()

    # -- clock ---------------------------------------------------------
    @staticmethod
    def now() -> float:
        return time.perf_counter()

    # -- recording -----------------------------------------------------
    def _push(self, rec: tuple) -> None:
        global RECORDS_TOTAL
        RECORDS_TOTAL += 1
        self._buf[self._idx % self.capacity] = rec
        self._idx += 1

    def span(
        self,
        track: Track,
        name: str,
        t0: float,
        t1: Optional[float] = None,
        args: Optional[Dict[str, Any]] = None,
        flow_out: Optional[int] = None,
        flow_in: Optional[int] = None,
    ) -> None:
        """Record a complete span [t0, t1] (t1 defaults to now)."""
        if t1 is None:
            t1 = time.perf_counter()
        self._push(("X", track, name, t0, t1, args, flow_out, flow_in))

    def instant(
        self,
        track: Track,
        name: str,
        t: Optional[float] = None,
        args: Optional[Dict[str, Any]] = None,
    ) -> None:
        if t is None:
            t = time.perf_counter()
        self._push(("I", track, name, t, args))

    def counter(
        self,
        track: Track,
        name: str,
        values: Dict[str, float],
        t: Optional[float] = None,
    ) -> None:
        if t is None:
            t = time.perf_counter()
        self._push(("C", track, name, t, values))

    def flow(self, track: Track, phase: str, fid: int, t: Optional[float] = None) -> None:
        """Record a bare flow endpoint (phase 's' start / 'f' finish)."""
        if phase not in ("s", "f"):
            raise ValueError(f"flow phase must be 's' or 'f', got {phase!r}")
        if t is None:
            t = time.perf_counter()
        self._push(("F", track, phase, fid, t))

    # -- inspection ----------------------------------------------------
    @property
    def events(self) -> int:
        """Total records ever pushed (including dropped ones)."""
        return self._idx

    @property
    def dropped(self) -> int:
        return max(0, self._idx - self.capacity)

    def records(self) -> List[tuple]:
        """Retained records, oldest first."""
        if self._idx <= self.capacity:
            return [r for r in self._buf[: self._idx]]
        head = self._idx % self.capacity
        return [r for r in self._buf[head:] + self._buf[:head]]

    def clear(self) -> None:
        self._buf = [None] * self.capacity
        self._idx = 0
