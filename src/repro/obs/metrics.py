"""Counters / gauges / histograms with Prometheus text exposition.

A tiny dependency-free metrics substrate: the engine observes request
latencies (TTFT/TPOT histograms) and token counters live, and folds
windowed utilization stats into gauges at export time.  Instances are
keyed by ``(name, sorted-label-items)`` so repeated lookups return the
same object — observation sites can hold a reference and skip the
registry dict on the hot path.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

# Explicit latency buckets (seconds).  TTFT spans sub-ms CPU smoke runs
# up to multi-second cold prefills; TPOT is per-token so sits an order
# of magnitude lower.
TTFT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0)
TPOT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 1.0)


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        self.value += amount

    def reset(self) -> None:
        self.value = 0.0


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def reset(self) -> None:
        self.value = 0.0


class Histogram:
    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: Sequence[float]):
        bs = tuple(float(b) for b in buckets)
        if list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError("histogram buckets must be strictly increasing")
        self.buckets = bs
        self.counts = [0] * len(bs)  # per-bucket (non-cumulative) counts
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.sum += value
        self.count += 1
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                self.counts[i] += 1
                return
        # falls through to the implicit +Inf bucket (count only)

    def reset(self) -> None:
        self.counts = [0] * len(self.buckets)
        self.sum = 0.0
        self.count = 0


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class MetricsRegistry:
    """Name → labeled metric instances, with Prometheus text export."""

    def __init__(self) -> None:
        # name -> (type, help, buckets-or-None, {label_key: instance})
        self._metrics: Dict[str, Tuple[str, str, Optional[tuple], Dict[LabelKey, object]]] = {}

    def _get(self, kind: str, name: str, help: str, labels: Dict[str, str],
             buckets: Optional[Sequence[float]] = None):
        ent = self._metrics.get(name)
        if ent is None:
            ent = (kind, help, tuple(buckets) if buckets is not None else None, {})
            self._metrics[name] = ent
        elif ent[0] != kind:
            raise ValueError(f"metric {name} already registered as {ent[0]}, not {kind}")
        key = _label_key(labels)
        inst = ent[3].get(key)
        if inst is None:
            if kind == "counter":
                inst = Counter()
            elif kind == "gauge":
                inst = Gauge()
            else:
                inst = Histogram(ent[2] or ())
            ent[3][key] = inst
        return inst

    def counter(self, name: str, help: str = "", **labels: str) -> Counter:
        return self._get("counter", name, help, labels)  # type: ignore[return-value]

    def gauge(self, name: str, help: str = "", **labels: str) -> Gauge:
        return self._get("gauge", name, help, labels)  # type: ignore[return-value]

    def histogram(self, name: str, buckets: Sequence[float], help: str = "",
                  **labels: str) -> Histogram:
        return self._get("histogram", name, help, labels, buckets)  # type: ignore[return-value]

    # -- inspection ----------------------------------------------------
    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, float]:
        """Flat {name{labels}: value} view (histograms as _sum/_count)."""
        out: Dict[str, float] = {}
        for name in sorted(self._metrics):
            kind, _, _, insts = self._metrics[name]
            for key in sorted(insts):
                inst = insts[key]
                ls = _label_str(key)
                if kind == "histogram":
                    out[f"{name}_sum{ls}"] = inst.sum  # type: ignore[union-attr]
                    out[f"{name}_count{ls}"] = float(inst.count)  # type: ignore[union-attr]
                else:
                    out[f"{name}{ls}"] = inst.value  # type: ignore[union-attr]
        return out

    def reset(self) -> None:
        for _, (_, _, _, insts) in self._metrics.items():
            for inst in insts.values():
                inst.reset()  # type: ignore[union-attr]

    # -- export --------------------------------------------------------
    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            kind, help, _, insts = self._metrics[name]
            if help:
                lines.append(f"# HELP {name} {help}")
            lines.append(f"# TYPE {name} {kind}")
            for key in sorted(insts):
                inst = insts[key]
                if kind == "histogram":
                    h: Histogram = inst  # type: ignore[assignment]
                    cum = 0
                    for ub, c in zip(h.buckets, h.counts):
                        cum += c
                        lk = _label_str(key + (("le", _fmt(ub)),))
                        lines.append(f"{name}_bucket{lk} {cum}")
                    lk = _label_str(key + (("le", "+Inf"),))
                    lines.append(f"{name}_bucket{lk} {h.count}")
                    lines.append(f"{name}_sum{_label_str(key)} {_fmt(h.sum)}")
                    lines.append(f"{name}_count{_label_str(key)} {h.count}")
                else:
                    lines.append(f"{name}{_label_str(key)} {_fmt(inst.value)}")  # type: ignore[union-attr]
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))
