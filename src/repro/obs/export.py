"""Exporters: Chrome/Perfetto trace-event JSON and Prometheus text files.

The Perfetto exporter maps tracer tracks onto one process with a named
thread per track — ``prefill stage j`` and ``decode replica r`` render
as parallel timelines in ui.perfetto.dev — and emits B/E pairs from the
tracer's *complete* span records, so every ``B`` has an ``E`` by
construction.  Request flows (admission → retirement) become ``s``/``f``
flow events keyed by request uid.

``validate_perfetto`` is the same check CI runs on the smoke artifact:
structural well-formedness, balanced B/E per thread, and flow-id
resolution.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .metrics import MetricsRegistry
from .trace import Tracer

PID = 1
_TID_TICK = 1
_TID_REQUESTS = 2
_TID_STAGE0 = 10
_TID_REPLICA0 = 100
_TID_OTHER0 = 1000


def _track_tid(track: Any, other: Dict[str, int]) -> Tuple[int, str]:
    """Map a tracer track to a stable (tid, display name)."""
    if track == "tick":
        return _TID_TICK, "engine"
    if track == "requests":
        return _TID_REQUESTS, "requests"
    if isinstance(track, tuple) and len(track) == 2:
        kind, idx = track
        if kind == "stage":
            return _TID_STAGE0 + int(idx), f"prefill stage {idx}"
        if kind == "replica":
            return _TID_REPLICA0 + int(idx), f"decode replica {idx}"
    name = str(track)
    if name not in other:
        other[name] = _TID_OTHER0 + len(other)
    return other[name], name


def perfetto_events(tracer: Tracer) -> List[Dict[str, Any]]:
    """Convert retained tracer records into trace_event dicts."""
    events: List[Dict[str, Any]] = []
    other: Dict[str, int] = {}
    seen_tids: Dict[int, str] = {}
    t0 = tracer.t0

    def us(t: float) -> float:
        return (t - t0) * 1e6

    for rec in tracer.records():
        kind = rec[0]
        if kind == "X":
            _, track, name, ts0, ts1, args, flow_out, flow_in = rec
            tid, tname = _track_tid(track, other)
            seen_tids.setdefault(tid, tname)
            base = {"pid": PID, "tid": tid, "name": name, "cat": "serving"}
            events.append({**base, "ph": "B", "ts": us(ts0), "args": args or {}})
            events.append({**base, "ph": "E", "ts": us(ts1)})
            mid = us((ts0 + ts1) / 2.0)
            if flow_out is not None:
                events.append({"ph": "s", "pid": PID, "tid": tid, "ts": mid,
                               "id": str(flow_out), "cat": "request", "name": "req"})
            if flow_in is not None:
                events.append({"ph": "f", "bp": "e", "pid": PID, "tid": tid,
                               "ts": mid, "id": str(flow_in), "cat": "request",
                               "name": "req"})
        elif kind == "I":
            _, track, name, ts, args = rec
            tid, tname = _track_tid(track, other)
            seen_tids.setdefault(tid, tname)
            events.append({"ph": "i", "pid": PID, "tid": tid, "ts": us(ts),
                           "name": name, "cat": "serving", "s": "t",
                           "args": args or {}})
        elif kind == "C":
            _, track, name, ts, values = rec
            tid, tname = _track_tid(track, other)
            seen_tids.setdefault(tid, tname)
            events.append({"ph": "C", "pid": PID, "tid": tid, "ts": us(ts),
                           "name": name, "args": dict(values)})
        elif kind == "F":
            _, track, phase, fid, ts = rec
            tid, tname = _track_tid(track, other)
            seen_tids.setdefault(tid, tname)
            ev = {"ph": phase, "pid": PID, "tid": tid, "ts": us(ts),
                  "id": str(fid), "cat": "request", "name": "req"}
            if phase == "f":
                ev["bp"] = "e"
            events.append(ev)

    events.sort(key=lambda e: e["ts"])
    meta: List[Dict[str, Any]] = [
        {"ph": "M", "pid": PID, "tid": 0, "name": "process_name",
         "args": {"name": "repro-serving"}},
    ]
    for tid in sorted(seen_tids):
        meta.append({"ph": "M", "pid": PID, "tid": tid, "name": "thread_name",
                     "args": {"name": seen_tids[tid]}})
        meta.append({"ph": "M", "pid": PID, "tid": tid, "name": "thread_sort_index",
                     "args": {"sort_index": tid}})
    return meta + events


def to_perfetto(tracer: Tracer) -> Dict[str, Any]:
    return {
        "traceEvents": perfetto_events(tracer),
        "displayTimeUnit": "ms",
        "otherData": {"dropped_records": tracer.dropped,
                      "total_records": tracer.events},
    }


def write_trace(tracer: Tracer, path: str) -> None:
    with open(path, "w") as f:
        json.dump(to_perfetto(tracer), f)


def write_metrics(reg: MetricsRegistry, path: str) -> None:
    with open(path, "w") as f:
        f.write(reg.to_prometheus())


def validate_perfetto(obj: Any, require_names: Sequence[str] = ()) -> List[str]:
    """Structural validation of a Perfetto trace_event JSON object.

    Returns a list of problems (empty = valid): top-level shape, every
    ``B`` matched by an ``E`` on its thread (names must pair up), every
    flow-finish ``f`` id resolved by a flow-start ``s``, and — when
    ``require_names`` is given — presence of each required event name.
    """
    problems: List[str] = []
    if not isinstance(obj, dict) or not isinstance(obj.get("traceEvents"), list):
        return ["top-level object must be a dict with a traceEvents list"]
    events = obj["traceEvents"]
    stacks: Dict[Tuple[int, int], List[str]] = {}
    flow_s: set = set()
    flow_f: set = set()
    names: set = set()
    for i, e in enumerate(events):
        if not isinstance(e, dict) or "ph" not in e:
            problems.append(f"event {i}: not a dict with a ph field")
            continue
        ph = e["ph"]
        if ph != "M" and not isinstance(e.get("ts"), (int, float)):
            problems.append(f"event {i} ({ph}): missing numeric ts")
            continue
        names.add(e.get("name"))
        key = (e.get("pid", 0), e.get("tid", 0))
        if ph == "B":
            stacks.setdefault(key, []).append(e.get("name", ""))
        elif ph == "E":
            st = stacks.setdefault(key, [])
            if not st:
                problems.append(f"event {i}: E without open B on pid/tid {key}")
            else:
                top = st.pop()
                if e.get("name") and e["name"] != top:
                    problems.append(
                        f"event {i}: E name {e['name']!r} does not close B {top!r}")
        elif ph == "s":
            flow_s.add(e.get("id"))
        elif ph == "f":
            flow_f.add(e.get("id"))
    for key, st in stacks.items():
        if st:
            problems.append(f"unclosed B events on pid/tid {key}: {st}")
    unresolved = flow_f - flow_s
    if unresolved:
        problems.append(f"flow finish ids without a start: {sorted(unresolved)[:8]}")
    for name in require_names:
        if name not in names:
            problems.append(f"required event name missing: {name!r}")
    return problems
