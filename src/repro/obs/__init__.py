"""Observability for the serving engine: tracing, metrics, exporters.

- ``trace``: ring-buffered monotonic-clock :class:`Tracer` (strictly
  no-op when disabled) and :class:`TraceConfig`.
- ``metrics``: :class:`MetricsRegistry` of counters/gauges/histograms
  with Prometheus text exposition.
- ``derive``: typed :class:`TrafficSnapshot` for the adaptive
  controller and trace-derived utilization views.
- ``export``: Chrome/Perfetto trace_event JSON writer + validator,
  Prometheus file writer.
"""
from .derive import TrafficSnapshot, fold_engine_metrics, utilization_from_trace
from .metrics import (
    TPOT_BUCKETS,
    TTFT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from .trace import TraceConfig, Tracer
from .export import to_perfetto, validate_perfetto, write_metrics, write_trace

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "TPOT_BUCKETS",
    "TTFT_BUCKETS",
    "TraceConfig",
    "Tracer",
    "TrafficSnapshot",
    "fold_engine_metrics",
    "to_perfetto",
    "utilization_from_trace",
    "validate_perfetto",
    "write_metrics",
    "write_trace",
]
