"""End-to-end serving driver (the paper's application kind is inference):
serve staggered requests through the per-slot continuous-batching engine
and report latency/throughput — the measured analogue of the paper's
latency-throughput tradeoff.

    PYTHONPATH=src python examples/serve_e2e.py [--requests 12] [--slots 4]

Requests arrive mid-stream (submitted between engine ticks): admission
prefills only the admitted slot into the persistent slot cache, so
in-flight decodes are never restarted — sweeping --slots trades latency
(fewer slots = less queueing) against throughput (more slots = fuller
decode batches), the same tradeoff axis as the paper's batch sweeps
(Fig. 2), measured on the real serving path.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import REGISTRY, reduced
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(REGISTRY[args.arch])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    eng = ServingEngine(model, params, slots=args.slots, max_seq=128)
    reqs = [Request(uid, rng.integers(1, cfg.vocab_size,
                                      size=rng.integers(3, 12))
                    .astype(np.int32), args.new_tokens)
            for uid in range(args.requests)]
    # staggered arrivals: half up front, the rest trickle in between ticks
    # (each admission prefills ONE slot; other slots keep decoding).
    t0 = time.perf_counter()
    pending = list(reqs)
    for _ in range(max(args.requests // 2, 1)):
        eng.submit(pending.pop(0))
    busy = True
    while busy or pending:
        if pending:
            eng.submit(pending.pop(0))
        busy = eng.tick()
    wall = time.perf_counter() - t0

    st = eng.stats()
    ttfts, lats = st["ttft_s"], st["latency_s"]
    print(f"requests={st['requests']} slots={args.slots} "
          f"tokens={st['gen_tokens']} wall={wall:.2f}s "
          f"occupancy={st['slot_occupancy']:.2f}")
    print(f"throughput: {st['gen_tokens'] / wall:.1f} tok/s")
    print(f"TTFT   p50={np.percentile(ttfts, 50)*1e3:.1f}ms "
          f"p95={np.percentile(ttfts, 95)*1e3:.1f}ms")
    print(f"latency p50={np.percentile(lats, 50)*1e3:.1f}ms "
          f"p95={np.percentile(lats, 95)*1e3:.1f}ms")


if __name__ == "__main__":
    main()
