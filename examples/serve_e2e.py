"""End-to-end serving driver (the paper's application kind is inference):
serve a small model with batched requests through the continuous-batching
engine, and report latency/throughput per request — the measured analogue of
the paper's latency-throughput tradeoff.

    PYTHONPATH=src python examples/serve_e2e.py [--requests 12] [--slots 4]

Sweeping --slots trades latency (fewer slots = less queueing per request)
against throughput (more slots = fuller batches) — the same tradeoff axis as
the paper's batch sweeps (Fig. 2), measured on the real serving path.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import REGISTRY, reduced
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(REGISTRY[args.arch])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    eng = ServingEngine(model, params, slots=args.slots, max_seq=128)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size,
                              size=rng.integers(3, 12)).astype(np.int32)
        eng.submit(Request(uid, prompt, args.new_tokens))
    done = eng.run()
    wall = time.perf_counter() - t0

    total_tokens = sum(len(r.out_tokens) for r in done)
    ttfts = [r.t_first - r.t_submit for r in done]
    lats = [r.t_done - r.t_submit for r in done]
    print(f"requests={len(done)} slots={args.slots} "
          f"tokens={total_tokens} wall={wall:.2f}s")
    print(f"throughput: {total_tokens / wall:.1f} tok/s")
    print(f"TTFT   p50={np.percentile(ttfts, 50)*1e3:.1f}ms "
          f"p95={np.percentile(ttfts, 95)*1e3:.1f}ms")
    print(f"latency p50={np.percentile(lats, 50)*1e3:.1f}ms "
          f"p95={np.percentile(lats, 95)*1e3:.1f}ms")


if __name__ == "__main__":
    main()
