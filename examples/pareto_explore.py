"""SSR design-space exploration — the paper's core workflow (§4):

given an architecture and a target platform, run the Layer→Acc evolutionary
search across accelerator counts and batch pipelining depths, and print the
latency-throughput Pareto front with the winning strategy per point
(paper Fig. 2 / Table 6).

    PYTHONPATH=src python examples/pareto_explore.py --arch deit-t --plat vck190
    PYTHONPATH=src python examples/pareto_explore.py --arch yi-6b \
        --shape prefill_32k --plat tpu
"""
import argparse

from repro.configs import REGISTRY, SHAPES
from repro.configs.deit import vit_shape
from repro.core import build_graph, pareto_front, strategy_points
from repro.core.hw import TPU_V5E


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deit-t")
    ap.add_argument("--shape", default="")
    ap.add_argument("--plat", default="vck190", choices=["vck190", "tpu"])
    ap.add_argument("--batch", type=int, default=6)
    args = ap.parse_args()

    cfg = REGISTRY[args.arch]
    if args.plat == "vck190":
        from benchmarks.common import BOARD_UNITS, VCK190_UNIT
        hw, chips = VCK190_UNIT, BOARD_UNITS
        shape = vit_shape(args.batch) if cfg.family == "vision" \
            else SHAPES[args.shape or "prefill_32k"]
        gran = "op"
    else:
        hw, chips = TPU_V5E, 256
        shape = SHAPES[args.shape or "prefill_32k"]
        gran = "block"

    g = build_graph(cfg, shape, granularity=gran)
    print(f"graph: {len(g.nodes)} nodes, "
          f"{g.total_mm_flops/1e12:.2f} TFLOP total on {hw.name} x{chips}")
    pts = strategy_points(g, chips, hw=hw, batches=(1, 2, 4, 6),
                          hybrid_accs=(2, 4), ea_iters=4)
    front = pareto_front(pts)

    print(f"\n{'strategy':12s} {'accs':>4s} {'batches':>7s} "
          f"{'latency_ms':>11s} {'TOPS':>8s}  on_front")
    fs = set(id(p) for p in front)
    for p in sorted(pts, key=lambda p: p.latency):
        mark = "  *" if id(p) in fs else ""
        print(f"{p.strategy:12s} {p.n_acc:4d} {p.n_batches:7d} "
              f"{p.latency*1e3:11.3f} {p.throughput_tops:8.2f}{mark}")
    print(f"\nPareto front: {len(front)} points "
          f"({sum(1 for p in front if p.strategy == 'hybrid')} hybrid)")


if __name__ == "__main__":
    main()
