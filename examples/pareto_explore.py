"""SSR design-space exploration — the paper's core workflow (§4):

given an architecture and a target platform, run the Layer→Acc evolutionary
search across accelerator counts and batch pipelining depths, print the
latency-throughput Pareto front with the winning strategy per point
(paper Fig. 2 / Table 6), and lower the best hybrid design to a runnable
``ExecutionPlan`` (block-granularity graphs) — the search → plan → execute
spine.

    PYTHONPATH=src python examples/pareto_explore.py --arch deit-t --plat vck190
    PYTHONPATH=src python examples/pareto_explore.py --arch yi-6b \
        --shape prefill_32k --plat tpu
"""
import argparse

from repro.configs import REGISTRY, SHAPES
from repro.configs.deit import vit_shape
from repro.core import build_graph, pareto_front, strategy_points
from repro.core.ea import evolutionary_search
from repro.core.hw import TPU_V5E


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deit-t")
    ap.add_argument("--shape", default="")
    ap.add_argument("--plat", default="vck190", choices=["vck190", "tpu"])
    ap.add_argument("--batch", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = REGISTRY[args.arch]
    if args.plat == "vck190":
        from benchmarks.common import BOARD_UNITS, VCK190_UNIT
        hw, chips = VCK190_UNIT, BOARD_UNITS
        shape = vit_shape(args.batch) if cfg.family == "vision" \
            else SHAPES[args.shape or "prefill_32k"]
        gran = "op"
    else:
        hw, chips = TPU_V5E, 256
        shape = SHAPES[args.shape or "prefill_32k"]
        gran = "block"

    g = build_graph(cfg, shape, granularity=gran)
    print(f"graph: {len(g.nodes)} nodes, "
          f"{g.total_mm_flops/1e12:.2f} TFLOP total on {hw.name} x{chips}")
    pts = strategy_points(g, chips, hw=hw, batches=(1, 2, 4, 6),
                          hybrid_accs=(2, 4), ea_iters=4, seed=args.seed)
    front = pareto_front(pts)

    print(f"\n{'strategy':12s} {'accs':>4s} {'batches':>7s} "
          f"{'latency_ms':>11s} {'TOPS':>8s}  on_front")
    fs = set(id(p) for p in front)
    for p in sorted(pts, key=lambda p: p.latency):
        mark = "  *" if id(p) in fs else ""
        print(f"{p.strategy:12s} {p.n_acc:4d} {p.n_batches:7d} "
              f"{p.latency*1e3:11.3f} {p.throughput_tops:8.2f}{mark}")
    print(f"\nPareto front: {len(front)} points "
          f"({sum(1 for p in front if p.strategy == 'hybrid')} hybrid)")

    # ---- lower the best hybrid front point to a runnable ExecutionPlan ----
    # (block-granularity graphs only: op-granularity nodes have no 1:1
    # mapping onto the scanned layer stack)
    hyb = [p for p in front if p.strategy == "hybrid"] or \
        [p for p in pts if p.strategy == "hybrid"]
    if gran != "block":
        print("\n(plan lowering needs a block-granularity graph; "
              "rerun with --plat tpu)")
        return
    if not hyb:
        print("\n(no hybrid point to lower)")
        return
    from repro.plan import lower, predict_plan
    best = max(hyb, key=lambda p: p.throughput_tops)
    res = evolutionary_search(g, chips, n_acc=best.n_acc,
                              n_batches=best.n_batches, n_pop=8, n_child=8,
                              n_iter=4, seed=args.seed, hw=hw)
    plan = lower(res.assignment, g, mesh_devices=chips,
                 n_rounds=best.n_batches)
    print(f"\nlowered best hybrid (accs={best.n_acc}, "
          f"batches={best.n_batches}):")
    print(plan.describe())
    pred = predict_plan(plan, g, hw=hw)
    print(f"realized prediction: makespan={pred['makespan_s']*1e3:.3f}ms "
          f"tops={pred['throughput_tops']:.2f} "
          f"(replicate-padding waste={pred['padding_waste']:.2f})")


if __name__ == "__main__":
    main()
