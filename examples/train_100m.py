"""End-to-end training driver: train the ~125M-parameter xLSTM config (the
smallest assigned arch at full size) for a few hundred steps with
checkpointing and fault-tolerant resume.

    PYTHONPATH=src python examples/train_100m.py --steps 300     # full run
    PYTHONPATH=src python examples/train_100m.py --steps 10      # smoke

Restarting the same command resumes from the latest checkpoint (kill it
mid-run to exercise the fault-tolerance path).  On CPU this uses a short
sequence length; on a real pod, pass --seq 4096 and shard via
repro.sharding (see launch/train.py for the pjit version).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, latest_step
from repro.configs import REGISTRY, ShapeConfig
from repro.data import SyntheticLM
from repro.models import build_model
from repro.training import AdamW, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = REGISTRY["xlstm-125m"]
    model = build_model(cfg)
    opt = AdamW(lr=3e-4, warmup_steps=20, total_steps=max(args.steps, 100))
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    data = SyntheticLM(cfg, shape)
    step_fn = jax.jit(make_train_step(model, opt, remat=True))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    start = 0
    prev = latest_step(args.ckpt_dir)
    params = model.init(jax.random.key(0))
    opt_state = opt.init(params)
    if prev is not None:
        restored, start = mgr.restore_latest(
            {"params": params, "opt": opt_state})
        params = restored["params"]
        o = restored["opt"]
        opt_state = type(opt_state)(step=jnp.asarray(o[0]), m=o[1], v=o[2]) \
            if isinstance(o, (list, tuple)) else o
        print(f"resumed from checkpoint at step {start}")
    print(f"params: {model.param_count(params)/1e6:.1f}M")

    for i in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, data.batch_at(i))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i}: loss={float(metrics['loss']):.4f}")
        if (i + 1) % args.ckpt_every == 0:
            mgr.save({"params": params, "opt": opt_state}, i + 1)
    mgr.save({"params": params, "opt": opt_state}, args.steps)
    mgr.wait()
    print("training done; checkpoint saved")


if __name__ == "__main__":
    main()
