"""Quickstart: build an assigned architecture, train a few steps, checkpoint,
restore, and run a decode — the whole substrate in one script.

    PYTHONPATH=src python examples/quickstart.py [--arch yi-6b] [--steps 5]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import restore, save
from repro.configs import REGISTRY, ShapeConfig, reduced
from repro.data import SyntheticLM
from repro.models import build_model
from repro.serving import Request, ServingEngine
from repro.training import AdamW, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=sorted(REGISTRY))
    ap.add_argument("--steps", type=int, default=5)
    args = ap.parse_args()

    cfg = reduced(REGISTRY[args.arch])     # smoke-sized, same family
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model}")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    print(f"params: {model.param_count(params):,}")

    opt = AdamW(lr=1e-3, warmup_steps=2, total_steps=max(args.steps, 10))
    opt_state = opt.init(params)
    data = SyntheticLM(cfg, ShapeConfig("quick", 64, 4, "train"))
    step_fn = jax.jit(make_train_step(model, opt, remat=True, grad_accum=2))

    for i in range(args.steps):
        batch = jax.tree.map(jnp.asarray, data.batch_at(i))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        print(f"step {i}: loss={float(metrics['loss']):.4f} "
              f"lr={float(metrics['lr']):.2e} "
              f"|g|={float(metrics['grad_norm']):.3f}")

    with tempfile.TemporaryDirectory() as d:
        save({"params": params, "opt": opt_state}, d, args.steps)
        restored, step = restore({"params": params, "opt": opt_state}, d)
        print(f"checkpoint roundtrip at step {step}: OK")

    if cfg.family not in ("vision", "audio", "vlm"):
        import numpy as np
        eng = ServingEngine(model, params, slots=2, max_seq=64)
        eng.submit(Request(0, np.array([1, 2, 3], np.int32), 8))
        done = eng.run()
        print(f"decoded tokens: {done[0].out_tokens}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
