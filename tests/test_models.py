"""Per-architecture smoke tests (reduced configs) + decode consistency."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, PAPER_MODELS, REGISTRY, reduced
from repro.models import build_model

B, S = 2, 32


def make_batch(cfg, b=B, s=S, seed=0):
    r = np.random.default_rng(seed)
    if cfg.family == "vision":
        return {"embeds": jnp.asarray(r.standard_normal((b, s, cfg.d_model)),
                                      jnp.float32),
                "labels": jnp.asarray(r.integers(0, cfg.vocab_size, (b,)),
                                      jnp.int32)}
    if cfg.family == "audio":
        dec = max(s // 4, 8)
        return {"enc_embeds": jnp.asarray(
                    r.standard_normal((b, s, cfg.d_model)), jnp.float32),
                "dec_tokens": jnp.asarray(
                    r.integers(0, cfg.vocab_size, (b, dec)), jnp.int32),
                "labels": jnp.asarray(
                    r.integers(0, cfg.vocab_size, (b, dec)), jnp.int32)}
    batch = {"labels": jnp.asarray(r.integers(0, cfg.vocab_size, (b, s)),
                                   jnp.int32)}
    if cfg.family == "vlm":
        batch["embeds"] = jnp.asarray(
            r.standard_normal((b, s, cfg.d_model)), jnp.float32)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, s))
    else:
        batch["tokens"] = jnp.asarray(r.integers(0, cfg.vocab_size, (b, s)),
                                      jnp.int32)
    return batch


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step, shapes + no NaNs."""
    cfg = reduced(REGISTRY[arch])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    logits, aux = jax.jit(model.forward)(params, batch)
    if cfg.family == "vision":
        assert logits.shape == (B, cfg.vocab_size)
    elif cfg.family == "audio":
        assert logits.shape == (B, max(S // 4, 8), cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), arch

    from repro.training import AdamW, make_train_step
    opt = AdamW(lr=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(make_train_step(model, opt, remat=False))
    p2, st2, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"])), arch
    # params actually changed
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p2),
                                jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_prefill_decode(arch):
    cfg = reduced(REGISTRY[arch])
    if cfg.family == "vision":
        pytest.skip("encoder-only: no decode step")
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    batch = make_batch(cfg)
    max_seq = 64
    if cfg.family == "audio":
        logits, cache = jax.jit(
            lambda p, b: model.prefill(p, b, max_seq))(params, batch)
        plen = batch["dec_tokens"].shape[1]
    else:
        b2 = dict(batch)
        b2.pop("labels")
        if cfg.family == "vlm":
            pytest.skip("vlm decode uses token path; covered by dry-run")
        logits, cache = jax.jit(
            lambda p, b: model.prefill(p, b, max_seq))(params, b2)
        plen = S
    tok = jnp.ones((B, 1), jnp.int32)
    logits2, cache = jax.jit(model.decode_step)(params, cache, tok,
                                                jnp.int32(plen))
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits2).any()), arch


@pytest.mark.parametrize("arch", ["yi-6b", "gemma2-9b", "xlstm-125m",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_forward(arch):
    """Autoregressive consistency: prefill(T) + decode(T..T+k) logits must
    match the full forward pass on the same prefix.

    MoE note: capacity-based routing drops tokens as a function of the
    *total* token count, which legitimately breaks prefix consistency (true
    of every capacity-routed MoE system).  We pin capacity_factor = E
    (⇒ per-round capacity = n, dropless) so the cache semantics are what's
    tested."""
    import dataclasses
    cfg = reduced(REGISTRY[arch])
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    r = np.random.default_rng(0)
    T, K = 16, 4
    toks = jnp.asarray(r.integers(0, cfg.vocab_size, (B, T + K)), jnp.int32)

    full, _ = jax.jit(model.forward)(params, {"tokens": toks})

    logits_p, cache = jax.jit(
        lambda p, b: model.prefill(p, b, T + K))(params,
                                                 {"tokens": toks[:, :T]})
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full[:, T - 1]),
                               atol=2e-4, rtol=2e-3)
    step = jax.jit(model.decode_step)
    for t in range(T, T + K):
        logits_d, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(full[:, t]),
                                   atol=2e-4, rtol=2e-3)


def test_sliding_window_ring_cache():
    """gemma2 local attention: decode past the window must match the full
    forward (ring buffer correctness)."""
    cfg = reduced(REGISTRY["gemma2-9b"])
    assert cfg.window_size == 16
    model = build_model(cfg)
    params = model.init(jax.random.key(3))
    r = np.random.default_rng(1)
    T, K = 20, 6                       # prefill beyond window (16)
    toks = jnp.asarray(r.integers(0, cfg.vocab_size, (B, T + K)), jnp.int32)
    full, _ = jax.jit(model.forward)(params, {"tokens": toks})
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, T + K))(
        params, {"tokens": toks[:, :T]})
    step = jax.jit(model.decode_step)
    for t in range(T, T + K):
        logits_d, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(full[:, t]),
                                   atol=3e-4, rtol=3e-3)


def test_mrope_positions_change_output():
    cfg = reduced(REGISTRY["qwen2-vl-72b"])
    model = build_model(cfg)
    params = model.init(jax.random.key(4))
    batch = make_batch(cfg)
    l1, _ = jax.jit(model.forward)(params, batch)
    b2 = dict(batch)
    b2["positions"] = batch["positions"] * 3
    l2, _ = jax.jit(model.forward)(params, b2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-6


def test_moe_aux_loss_finite_and_capacity_drops():
    cfg = reduced(REGISTRY["qwen2-moe-a2.7b"])
    model = build_model(cfg)
    params = model.init(jax.random.key(5))
    batch = make_batch(cfg)
    _, aux = jax.jit(model.forward)(params, batch)
    assert np.isfinite(float(aux)) and float(aux) >= 0


def test_whisper_decode_matches_forward():
    """Enc-dec consistency: decoder prefill + decode steps must match the
    full forward pass, and the encoder must actually influence decode
    (regression for the zero-cross_kv DCE bug)."""
    cfg = reduced(REGISTRY["whisper-base"])
    model = build_model(cfg)
    params = model.init(jax.random.key(7))
    r = np.random.default_rng(7)
    Td, K = 12, 4
    enc = jnp.asarray(r.standard_normal((B, 24, cfg.d_model)), jnp.float32)
    dec = jnp.asarray(r.integers(0, cfg.vocab_size, (B, Td + K)), jnp.int32)

    full, _ = jax.jit(model.forward)(
        params, {"enc_embeds": enc, "dec_tokens": dec})
    logits_p, cache = jax.jit(
        lambda p, b: model.prefill(p, b, Td + K))(
        params, {"enc_embeds": enc, "dec_tokens": dec[:, :Td]})
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full[:, Td - 1]),
                               atol=3e-4, rtol=3e-3)
    step = jax.jit(model.decode_step)
    for t in range(Td, Td + K):
        logits_d, cache = step(params, cache, dec[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(full[:, t]),
                                   atol=3e-4, rtol=3e-3)

    # encoder must matter: different audio -> different prefill logits
    enc2 = enc * 2.0 + 1.0
    logits_q, _ = jax.jit(lambda p, b: model.prefill(p, b, Td + K))(
        params, {"enc_embeds": enc2, "dec_tokens": dec[:, :Td]})
    assert float(jnp.max(jnp.abs(logits_q - logits_p))) > 1e-4
