"""Sharding rules: every produced spec must divide the corresponding dim
on the production meshes (validated on abstract meshes — no devices)."""
import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.backend.compat import make_abstract_mesh
from repro.configs import ARCHS, SHAPES, reduced
from repro.models import build_model
from repro.sharding import batch_axes_for, input_specs_tree, param_specs
from repro.sharding.rules import _fsdp_extend


def abstract_mesh(shape=(16, 16), axes=("data", "model")):
    return make_abstract_mesh(shape, axes)


def _check_divisible(specs, tree, mesh, label):
    for spec, leaf in zip(jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P)),
            jax.tree.leaves(tree)):
        entries = list(spec)
        for i, e in enumerate(entries):
            if e is None:
                continue
            names = e if isinstance(e, tuple) else (e,)
            div = int(np.prod([mesh.shape[n] for n in names]))
            assert leaf.shape[i] % div == 0, \
                (label, spec, leaf.shape, i, div)


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible_full_size(arch, multi_pod):
    """FULL configs: abstract-mesh spec check (no allocation)."""
    cfg = ARCHS[arch]
    mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model")) if multi_pod \
        else abstract_mesh()
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    for fsdp in (False, True):
        specs = param_specs(params, mesh, fsdp=fsdp)
        _check_divisible(specs, params, mesh, f"{arch} fsdp={fsdp}")


@pytest.mark.parametrize("arch", ["yi-6b", "jamba-1.5-large-398b",
                                  "whisper-base", "qwen2-vl-72b"])
@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k", "decode_32k"])
def test_input_specs_divisible(arch, shape):
    cfg = ARCHS[arch]
    mesh = abstract_mesh()
    model = build_model(cfg)
    specs_in = model.input_specs(SHAPES[shape])
    sspecs = input_specs_tree(specs_in, mesh)
    _check_divisible(sspecs, specs_in, mesh, f"{arch}/{shape}")


def test_batch_axes_prefix_logic():
    mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert batch_axes_for(mesh, 256) == ("pod", "data")
    assert batch_axes_for(mesh, 2) == ("pod",)
    assert batch_axes_for(mesh, 1) is None
    assert batch_axes_for(mesh, 32) == ("pod", "data")


def test_fsdp_never_shards_scan_axis_on_3d():
    mesh = abstract_mesh()
    leaf = jax.ShapeDtypeStruct((32, 4096, 1024), np.float32)
    spec = _fsdp_extend(P(None, None, "model"), leaf, mesh)
    assert spec[0] is None          # group/scan axis untouched
    assert "data" in spec


def test_vocab_indivisible_replicated():
    """granite vocab 49155 is indivisible by 16 -> embed must replicate."""
    cfg = ARCHS["granite-moe-1b-a400m"]
    mesh = abstract_mesh()
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    specs = param_specs(params, mesh)
    assert specs["embed"]["table"] == P(None, None)
