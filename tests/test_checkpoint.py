"""Fault tolerance: atomic checkpoints, corruption detection, retention,
restart-resume, elastic resharding."""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.checkpoint.manager import _flatten


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": jnp.zeros((), jnp.float32)}}


def test_roundtrip(tmp_path):
    t = tree()
    save(t, str(tmp_path), 7)
    r, step = restore(t, str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(r), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_wins(tmp_path):
    t = tree()
    save(t, str(tmp_path), 1)
    t2 = jax.tree.map(lambda x: x + 1, t)
    save(t2, str(tmp_path), 2)
    r, step = restore(t, str(tmp_path))
    assert step == 2
    np.testing.assert_array_equal(np.asarray(r["a"]),
                                  np.asarray(t["a"]) + 1)


def test_corruption_detected(tmp_path):
    t = tree()
    path = save(t, str(tmp_path), 1)
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        man = json.load(f)
    man["checksum"] = "0" * 64
    with open(mpath, "w") as f:
        json.dump(man, f)
    with pytest.raises(IOError, match="checksum"):
        restore(t, str(tmp_path))


def test_no_partial_checkpoint_visible(tmp_path):
    """tmp dirs never count as checkpoints (atomicity)."""
    os.makedirs(tmp_path / "tmp.5.123")
    assert latest_step(str(tmp_path)) is None


def test_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    t = tree()
    for s in range(5):
        mgr.save(jax.tree.map(lambda x: x + s, t), s)
    mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]
    r, step = mgr.restore_latest(t)
    assert step == 4


def test_restart_resumes_training(tmp_path):
    """Kill-and-restart: restored (params, opt, step) continue bit-identically
    vs an uninterrupted run."""
    from repro.configs import REGISTRY, ShapeConfig, reduced
    from repro.data import SyntheticLM
    from repro.models import build_model
    from repro.training import AdamW, make_train_step

    cfg = reduced(REGISTRY["yi-6b"], layers=1)
    model = build_model(cfg)
    opt = AdamW(lr=1e-3, warmup_steps=1, total_steps=10)
    data = SyntheticLM(cfg, ShapeConfig("t", 16, 2, "train"))
    step_fn = jax.jit(make_train_step(model, opt, remat=False))

    # uninterrupted: 4 steps
    p = model.init(jax.random.key(0))
    st = opt.init(p)
    for i in range(4):
        p, st, _ = step_fn(p, st, jax.tree.map(jnp.asarray, data.batch_at(i)))

    # interrupted at 2, checkpoint, "restart", resume at batch 2
    p2 = model.init(jax.random.key(0))
    st2 = opt.init(p2)
    for i in range(2):
        p2, st2, _ = step_fn(p2, st2,
                             jax.tree.map(jnp.asarray, data.batch_at(i)))
    save({"params": p2, "opt": st2, "data_step": 2}, str(tmp_path), 2)
    restored, _ = restore({"params": p2, "opt": st2, "data_step": 0},
                          str(tmp_path))
    p3, st3 = restored["params"], restored["opt"]
    st3 = type(st2)(step=jnp.asarray(st3[0]),
                    m=st3[1], v=st3[2]) if isinstance(st3, (list, tuple)) \
        else st3
    start = int(restored["data_step"])
    for i in range(start, 4):
        p3, st3, _ = step_fn(p3, st3,
                             jax.tree.map(jnp.asarray, data.batch_at(i)))
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p3)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)


def test_flatten_keys_stable():
    t = tree()
    assert set(_flatten(t)) == {"a", "b/c", "b/d"}
