"""HLO parsing (collective bytes + loop-aware dot flops) on toy modules."""
import numpy as np

from repro.launch.collectives import (_split_computations, collective_bytes,
                                      dot_flops)

TOY_HLO = """
HloModule toy

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %w = f32[8,8] constant({...})
  %dot.1 = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %a)
  %while.1 = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  %out = f32[8,8] get-tuple-element(%while.1), index=1
  %ag = f32[16,8]{1,0} all-gather(%out), dimensions={0}
  %dot.2 = f32[16,16]{1,0} dot(%ag, %ag), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  ROOT %r = f32[16,16] slice(%dot.2), slice={[0:16],[0:16]}
}
"""


def test_split_computations():
    comps = _split_computations(TOY_HLO)
    assert {"cond", "body", "main"} <= set(comps)


def test_collective_bytes_trip_weighted():
    out = collective_bytes(TOY_HLO)
    # all-reduce inside 10-trip loop: 10 * 8*8*4 bytes
    assert out["all-reduce"] == 10 * 8 * 8 * 4
    # all-gather at entry: 16*8*4
    assert out["all-gather"] == 16 * 8 * 4
    assert out["_total"] == out["all-reduce"] + out["all-gather"]


def test_dot_flops_trip_weighted():
    f = dot_flops(TOY_HLO)
    inner = 10 * 2 * 8 * 8 * 8          # 10 trips x 2*M*N*K
    outer = 2 * 16 * 16 * 8
    assert f == inner + outer


def test_dot_flops_symbol_table():
    """Post-optimization style: operand shapes not inline."""
    hlo = """
ENTRY %main (a: f32[4,8]) -> f32[4,4] {
  %a = f32[4,8] parameter(0)
  %b = f32[8,4]{1,0} custom-call(), custom_call_target="x"
  %dot.9 = f32[4,4]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %r = f32[4,4] add(%dot.9, %dot.9)
}
"""
    assert dot_flops(hlo) == 2 * 4 * 4 * 8


def test_real_module_roundtrip():
    """Parse a real jit-compiled module (1 device)."""
    import jax
    import jax.numpy as jnp

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    x = jnp.ones((8, 8))
    w = jnp.ones((8, 8))
    txt = jax.jit(f).lower(x, w).compile().as_text()
    flops = dot_flops(txt)
    assert flops >= 5 * 2 * 8 * 8 * 8, flops   # loop counted 5x
