"""Distribution tests that need >1 device: run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest process
keeps the default 1 device, per the dry-run isolation requirement)."""
import os
import subprocess
import sys
import textwrap

import pytest

# Each test compiles a model in a fresh 8-device subprocess: multi-second.
pytestmark = pytest.mark.slow

ENV = {**os.environ,
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}


def run_py(body: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       env=ENV, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import REGISTRY, reduced
        from repro.models import build_model
        from repro.sharding import param_shardings, input_shardings_tree
        from repro.launch.mesh import _make_mesh, use_mesh
        from repro.training import AdamW, make_train_step
        cfg = reduced(REGISTRY['yi-6b'])
        m = build_model(cfg)
        opt = AdamW(lr=1e-3, warmup_steps=1, total_steps=10)
        step = make_train_step(m, opt, remat=False)
        p = m.init(jax.random.key(0)); st = opt.init(p)
        B,S = 8, 32
        r = np.random.default_rng(0)
        batch = {'tokens': jnp.asarray(r.integers(0,cfg.vocab_size,(B,S)),jnp.int32),
                 'labels': jnp.asarray(r.integers(0,cfg.vocab_size,(B,S)),jnp.int32)}
        # single-device reference
        p1, st1, m1 = jax.jit(step)(p, st, batch)
        # sharded over (4 data, 2 model)
        mesh = _make_mesh((4,2), ('data','model'))
        ps = param_shardings(p, mesh)
        pp = jax.device_put(p, ps)
        bs = input_shardings_tree(batch, mesh)
        bb = jax.device_put(batch, bs)
        with use_mesh(mesh):
            p2, st2, m2 = jax.jit(step)(pp, opt.init(pp), bb)
        assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-4, (m1, m2)
        err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)-jnp.asarray(b).astype(jnp.float32))))
                  for a,b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert err < 1e-4, err
        print('sharded == single-device OK', err)
    """)


def test_fsdp_sharding_matches():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import REGISTRY, reduced
        from repro.models import build_model
        from repro.sharding import param_shardings, input_shardings_tree
        from repro.launch.mesh import _make_mesh, use_mesh
        cfg = reduced(REGISTRY['yi-6b'])
        m = build_model(cfg)
        p = m.init(jax.random.key(0))
        B,S = 8, 32
        batch = {'tokens': jnp.ones((B,S),jnp.int32), 'labels': jnp.ones((B,S),jnp.int32)}
        ref = jax.jit(m.loss)(p, batch)
        mesh = _make_mesh((4,2), ('data','model'))
        pp = jax.device_put(p, param_shardings(p, mesh, fsdp=True))
        bb = jax.device_put(batch, input_shardings_tree(batch, mesh))
        with use_mesh(mesh):
            out = jax.jit(m.loss)(pp, bb)
        assert abs(float(ref) - float(out)) < 1e-4
        print('fsdp OK')
    """)


def test_pipeline_executor_matches_forward():
    run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import REGISTRY, reduced
        from repro.models import build_model
        from repro.launch.mesh import _make_mesh, use_mesh
        from repro.pipeline import pipeline_forward
        cfg = reduced(REGISTRY['yi-6b'])   # 2 groups -> 2 stages
        m = build_model(cfg)
        B,S = 8, 32
        batch = {'tokens': jnp.ones((B,S),jnp.int32)}
        pmesh = _make_mesh((2,2,2), ('stage','data','model'))
        with use_mesh(pmesh):
            p = m.init(jax.random.key(0))
            got = pipeline_forward(m, p, batch, pmesh, n_stages=2, n_microbatches=4)
            ref, _ = m.forward(p, batch)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 1e-4, err
        print('pipeline OK', err)
    """)


def test_plan_executor_uneven_stages_matches_forward():
    """Round-trip acceptance: an EA/DSE hybrid assignment with uneven
    layer cuts and heterogeneous acc widths lowers to an ExecutionPlan
    whose executed forward (2 uneven stages on the stage-major mesh) is
    numerically identical to the non-pipelined reference."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import REGISTRY, reduced, ShapeConfig
        from repro.models import build_model
        from repro.core import build_graph, evolutionary_search, ssr_dse
        from repro.plan import lower
        from repro.pipeline import plan_forward
        from repro.launch.mesh import make_plan_mesh, use_mesh
        cfg = reduced(REGISTRY['yi-6b'], layers=3)   # 3 groups: uneven 2-stage
        m = build_model(cfg)
        B, S = 8, 32
        rng = np.random.default_rng(0)
        batch = {'tokens': jnp.asarray(
            rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)}
        g = build_graph(cfg, ShapeConfig('t', S, B, 'prefill'))
        # EA-searched genome, then force the uneven 2|1 cut through the same
        # DSE inner pass (customization => heterogeneous chips/dp/tp)
        ea = evolutionary_search(g, 8, n_acc=2, n_batches=2, n_pop=6,
                                 n_child=6, n_iter=3, seed=0)
        _, _, assign = ssr_dse(g, (0, 0, 0, 1, 1), 8, n_batches=2)
        assert assign.accs[0].chips != assign.accs[1].chips, assign.accs
        for a in (ea.assignment, assign):
            plan = lower(a, g, mesh_devices=8, n_microbatches=4)
            mesh = make_plan_mesh(plan)
            with use_mesh(mesh):
                p = m.init(jax.random.key(0))
                got = plan_forward(m, p, batch, mesh, plan)
                ref, _ = m.forward(p, batch)
            err = float(jnp.max(jnp.abs(got - ref)))
            assert err < 1e-4, (plan.describe(), err)
            print('plan OK', [s.n_groups for s in plan.stages], err)
        # sequential dimension: 2 rounds x 2 microbatches == 4 microbatches
        plan = lower(assign, g, mesh_devices=8, n_microbatches=2, n_rounds=2)
        mesh = make_plan_mesh(plan)
        with use_mesh(mesh):
            p = m.init(jax.random.key(0))
            got = plan_forward(m, p, batch, mesh, plan)
            ref, _ = m.forward(p, batch)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 1e-4, err
        print('rounds OK', err)
    """)


def test_elastic_reshard_across_meshes():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.configs import REGISTRY, reduced
        from repro.models import build_model
        from repro.sharding import param_shardings
        from repro.launch.mesh import _make_mesh
        from repro.checkpoint import save, restore
        cfg = reduced(REGISTRY['yi-6b'])
        m = build_model(cfg)
        p = m.init(jax.random.key(0))
        mesh8 = _make_mesh((4,2), ('data','model'))
        p8 = jax.device_put(p, param_shardings(p, mesh8))
        with tempfile.TemporaryDirectory() as d:
            save(p8, d, 1)
            # 'node failure': restart on a smaller 4-device mesh
            mesh4 = _make_mesh((2,2), ('data','model'))
            restored, _ = restore(p, d, shardings=param_shardings(p, mesh4))
        err = max(float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32)-jnp.asarray(b, jnp.float32))))
                  for a,b in zip(jax.tree.leaves(restored), jax.tree.leaves(p)))
        assert err == 0.0, err
        print('elastic reshard OK')
    """)


def test_moe_expert_parallel_option():
    run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import REGISTRY, reduced
        from repro.models import build_model
        from repro.sharding import param_shardings, input_shardings_tree
        from repro.launch.mesh import _make_mesh, use_mesh
        cfg = reduced(REGISTRY['qwen2-moe-a2.7b'])
        m = build_model(cfg)
        p = m.init(jax.random.key(0))
        batch = {'tokens': jnp.ones((8,32),jnp.int32), 'labels': jnp.ones((8,32),jnp.int32)}
        ref = jax.jit(m.loss)(p, batch)
        mesh = _make_mesh((2,4), ('data','model'))  # 4-way EP over 4 experts
        pp = jax.device_put(p, param_shardings(p, mesh, expert_parallel=True))
        bb = jax.device_put(batch, input_shardings_tree(batch, mesh))
        with use_mesh(mesh):
            out = jax.jit(m.loss)(pp, bb)
        assert abs(float(ref) - float(out)) < 1e-4, (ref, out)
        print('EP OK')
    """)
