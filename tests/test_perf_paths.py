"""Equivalence tests for the §Perf optimization paths: every beyond-paper
speed/memory lever must be numerically equivalent to the reference path."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduced
from repro.models import build_model


@pytest.fixture
def yi_model():
    cfg = reduced(REGISTRY["yi-6b"])
    m = build_model(cfg)
    p = m.init(jax.random.key(0))
    r = np.random.default_rng(0)
    toks = jnp.asarray(r.integers(0, cfg.vocab_size, (2, 64)), jnp.int32)
    return m, p, toks


def _with_env(key, val, fn):
    old = os.environ.get(key)
    os.environ[key] = val
    jax.clear_caches()
    try:
        return fn()
    finally:
        if old is None:
            os.environ.pop(key, None)
        else:
            os.environ[key] = old
        jax.clear_caches()


def test_chunked_attention_matches_full(yi_model):
    m, p, toks = yi_model
    chunked = _with_env("REPRO_CHUNKED_ATTN", "16",
                        lambda: jax.jit(m.forward)(p, {"tokens": toks})[0])
    full = _with_env("REPRO_CHUNKED_ATTN", "0",
                     lambda: jax.jit(m.forward)(p, {"tokens": toks})[0])
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               atol=2e-4, rtol=2e-4)


def test_mamba_fused_matches_chunked():
    cfg = reduced(REGISTRY["jamba-1.5-large-398b"])
    m = build_model(cfg)
    p = m.init(jax.random.key(1))
    r = np.random.default_rng(1)
    toks = jnp.asarray(r.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)
    fused = _with_env("REPRO_MAMBA", "fused",
                      lambda: jax.jit(m.forward)(p, {"tokens": toks})[0])
    chunk = _with_env("REPRO_MAMBA", "chunked",
                      lambda: jax.jit(m.forward)(p, {"tokens": toks})[0])
    np.testing.assert_allclose(np.asarray(fused), np.asarray(chunk),
                               atol=2e-4, rtol=2e-4)


def test_mamba_fused_scan_unit():
    """mamba_scan_fused vs the explicit a/b materialization + chunked scan."""
    from repro.models.ssm import linear_scan_chunked, mamba_scan_fused
    r = np.random.default_rng(2)
    B, S, di, n = 2, 64, 8, 4
    delta = jnp.asarray(r.uniform(0.01, 0.5, (B, S, di)), jnp.float32)
    xi = jnp.asarray(r.standard_normal((B, S, di)), jnp.float32)
    Bm = jnp.asarray(r.standard_normal((B, S, n)), jnp.float32)
    Cm = jnp.asarray(r.standard_normal((B, S, n)), jnp.float32)
    A = -jnp.asarray(r.uniform(0.1, 1.0, (di, n)), jnp.float32)
    y1, h1 = mamba_scan_fused(delta, xi, Bm, Cm, A, chunk=16)
    a = jnp.exp(delta[..., None] * A)
    b = (delta * xi)[..., None] * Bm[:, :, None, :]
    h_all, h2 = linear_scan_chunked(a, b, chunk=16)
    y2 = jnp.einsum("bsin,bsn->bsi", h_all, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=1e-5, rtol=1e-5)


def test_moe_per_round_capacity_no_drops_when_balanced():
    """After the k²-capacity fix: with capacity_factor=E (dropless) the MoE
    output must equal an explicit dense-dispatch computation."""
    import dataclasses

    from repro.models import layers as L
    cfg = reduced(REGISTRY["granite-moe-1b-a400m"])
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=float(cfg.moe.num_experts),
        num_shared_experts=0))
    r = np.random.default_rng(3)
    key = jax.random.key(4)
    p = L.init_moe(key, cfg)
    x = jnp.asarray(r.standard_normal((2, 8, cfg.d_model)) * 0.1,
                    jnp.float32)
    y, aux = L.apply_moe(p, x, cfg)

    # dense reference: route every token through its top-k explicitly
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_w, top_e = jax.lax.top_k(probs, cfg.moe.experts_per_token)
    top_w = top_w / jnp.sum(top_w, -1, keepdims=True)
    ref = jnp.zeros_like(xf)
    for t in range(xf.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.moe.experts_per_token):
            e = int(top_e[t, j])
            hid = xf[t] @ p["wi"][e]
            gate = xf[t] @ p["wg"][e]
            acc += float(top_w[t, j]) * ((jax.nn.silu(gate) * hid)
                                         @ p["wo"][e])
        ref = ref.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(ref), atol=2e-4, rtol=2e-3)


def test_sp_flag_is_noop_without_mesh(yi_model):
    m, p, toks = yi_model
    base = jax.jit(m.forward)(p, {"tokens": toks})[0]
    sp = _with_env("REPRO_SP", "1",
                   lambda: jax.jit(m.forward)(p, {"tokens": toks})[0])
    np.testing.assert_allclose(np.asarray(base), np.asarray(sp), atol=1e-6)


def test_chunked_ce_matches_standard():
    """Fused head+CE (online logsumexp over vocab chunks) vs standard path,
    including masked labels and logit softcapping (gemma2)."""
    for arch in ("yi-6b", "gemma2-9b"):
        cfg = reduced(REGISTRY[arch])
        m = build_model(cfg)
        p = m.init(jax.random.key(5))
        r = np.random.default_rng(5)
        labels = r.integers(0, cfg.vocab_size, (2, 32)).astype(np.int32)
        labels[0, :4] = -1          # masked prefix
        batch = {"tokens": jnp.asarray(
                     r.integers(0, cfg.vocab_size, (2, 32)), jnp.int32),
                 "labels": jnp.asarray(labels)}
        std = _with_env("REPRO_CHUNKED_CE", "0",
                        lambda: float(jax.jit(m.loss)(p, batch)))
        chunked = _with_env("REPRO_CHUNKED_CE", "1",
                            lambda: float(jax.jit(m.loss)(p, batch)))
        assert abs(std - chunked) < 1e-4, (arch, std, chunked)


def test_chunked_ce_grads_match():
    from repro.models import layers as L
    cfg = reduced(REGISTRY["yi-6b"])
    m = build_model(cfg)
    p = m.init(jax.random.key(6))
    r = np.random.default_rng(6)
    batch = {"tokens": jnp.asarray(
                 r.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
             "labels": jnp.asarray(
                 r.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)}
    g_std = _with_env("REPRO_CHUNKED_CE", "0",
                      lambda: jax.jit(jax.grad(m.loss))(p, batch))
    g_chk = _with_env("REPRO_CHUNKED_CE", "1",
                      lambda: jax.jit(jax.grad(m.loss))(p, batch))
    for a, b in zip(jax.tree.leaves(g_std), jax.tree.leaves(g_chk)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-3)
