"""Seeded determinism of the SSR evolutionary search: same seed must give
an identical best assignment and metrics, so DSE results (and the paper
tables derived from them) are reproducible in CI."""
from repro.configs import SHAPES, get_config
from repro.core import build_graph, evolutionary_search

KW = dict(n_acc=3, n_batches=2, n_pop=6, n_child=6, n_iter=2)


def graph():
    return build_graph(get_config("yi-6b"), SHAPES["train_4k"])


def test_evolutionary_search_same_seed_identical():
    g = graph()
    r1 = evolutionary_search(g, 256, seed=7, **KW)
    r2 = evolutionary_search(g, 256, seed=7, **KW)
    assert r1.assignment == r2.assignment          # frozen-dataclass equality
    assert r1.latency == r2.latency
    assert r1.throughput == r2.throughput
    assert r1.evaluations == r2.evaluations
    assert [h[1] for h in r1.history] == [h[1] for h in r2.history]


def test_evolutionary_search_uses_local_rng_only():
    """The search must not touch the global `random` module state (a
    driver seeding `random` differently around it must see no effect)."""
    import random

    g = graph()
    random.seed(1)
    r1 = evolutionary_search(g, 256, seed=11, **KW)
    random.seed(999)
    r2 = evolutionary_search(g, 256, seed=11, **KW)
    assert r1.assignment == r2.assignment
    assert r1.latency == r2.latency
