"""Observability layer: tracer ring, metrics registry, Perfetto export,
engine integration (no-op guarantee, parity, utilization, snapshots)."""
import json

import numpy as np
import pytest

import jax

import repro.obs.trace as trace_mod
from repro.configs import REGISTRY, reduced
from repro.models import build_model
from repro.obs import (MetricsRegistry, TraceConfig, Tracer, TrafficSnapshot,
                       to_perfetto, validate_perfetto, write_metrics,
                       write_trace)
from repro.obs.derive import utilization_from_trace
from repro.plan import lower_serving, uniform_plan
from repro.serving import AdaptiveConfig, Request, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(REGISTRY["yi-6b"], layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _plan(cfg, slots=2, chunk=4):
    return lower_serving(uniform_plan(cfg.num_groups, 2, n_microbatches=2),
                         slots=slots, chunk=chunk)


def _drive(eng, n=3, new_tokens=4):
    for i in range(n):
        eng.submit(Request(i, np.arange(1, 7 + i, dtype=np.int32),
                           new_tokens))
    eng.run()
    return [list(r.out_tokens) for r in sorted(eng.done, key=lambda r: r.uid)]


# ---------------------------------------------------------------------------
# trace core


def test_tracer_ring_wraps_and_counts_drops():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant("tick", f"e{i}", t=float(i))
    assert tr.events == 10
    assert tr.dropped == 6
    recs = tr.records()
    assert len(recs) == 4
    # oldest-first, and only the newest 4 survive the wrap
    assert [r[2] for r in recs] == ["e6", "e7", "e8", "e9"]
    tr.clear()
    assert tr.events == 0 and tr.records() == []


def test_tracer_records_complete_spans_only():
    """Spans enter the ring with both endpoints — a wrapped ring can
    never export a B without its E."""
    tr = Tracer(capacity=2)
    for i in range(5):
        tr.span("tick", "s", t0=float(i), t1=float(i) + 0.5)
    for rec in tr.records():
        assert rec[0] == "X" and rec[4] > rec[3]
    with pytest.raises(ValueError):
        tr.flow("tick", "x", 1)
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_perfetto_export_shape_and_validation():
    tr = Tracer()
    t = tr.t0
    tr.span("tick", "outer", t + 0.001, t + 0.005)
    tr.span(("stage", 0), "prefill_chunk", t + 0.002, t + 0.003,
            args={"tokens": 4})
    tr.span("requests", "admit", t + 0.002, t + 0.002, flow_out=7)
    tr.span("requests", "retire", t + 0.004, t + 0.004, flow_in=7)
    tr.instant("requests", "submit", t=t + 0.0005)
    tr.counter("tick", "engine", {"queue": 1}, t=t + 0.001)
    obj = to_perfetto(tr)
    assert not validate_perfetto(
        obj, require_names=("outer", "prefill_chunk", "submit"))
    ev = obj["traceEvents"]
    # B/E pairing per span, metadata names the tracks
    assert sum(1 for e in ev if e.get("ph") == "B") == \
        sum(1 for e in ev if e.get("ph") == "E") == 4
    meta = {e["args"]["name"] for e in ev
            if e.get("ph") == "M" and e["name"] == "thread_name"}
    assert {"engine", "requests", "prefill stage 0"} <= meta
    # flows resolve: one s and one f with the same id
    flows = [(e["ph"], e["id"]) for e in ev if e.get("ph") in ("s", "f")]
    assert ("s", "7") in flows and ("f", "7") in flows
    # validator catches a dangling flow finish
    tr2 = Tracer()
    tr2.span("requests", "retire", tr2.t0, tr2.t0 + 1e-4, flow_in=9)
    assert any("flow" in p for p in validate_perfetto(to_perfetto(tr2)))


# ---------------------------------------------------------------------------
# metrics registry


def test_metrics_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("repro_requests_total", help="requests").inc(3)
    reg.gauge("repro_occ", stage="0").set(0.5)
    reg.gauge("repro_occ", stage="1").set(0.25)
    h = reg.histogram("repro_lat_seconds", (0.01, 0.1, 1.0), help="lat")
    for v in (0.005, 0.05, 0.05, 5.0):
        h.observe(v)
    txt = reg.to_prometheus()
    assert "# TYPE repro_requests_total counter" in txt
    assert "repro_requests_total 3" in txt
    assert 'repro_occ{stage="0"} 0.5' in txt
    assert 'repro_occ{stage="1"} 0.25' in txt
    # histogram buckets are CUMULATIVE in the exposition, +Inf == count
    assert 'repro_lat_seconds_bucket{le="0.01"} 1' in txt
    assert 'repro_lat_seconds_bucket{le="0.1"} 3' in txt
    assert 'repro_lat_seconds_bucket{le="1"} 3' in txt
    assert 'repro_lat_seconds_bucket{le="+Inf"} 4' in txt
    assert "repro_lat_seconds_count 4" in txt
    # registry invariants
    assert reg.counter("repro_requests_total") is \
        reg.counter("repro_requests_total")
    with pytest.raises(ValueError):
        reg.gauge("repro_requests_total")
    with pytest.raises(ValueError):
        reg.counter("repro_requests_total").inc(-1)
    snap = reg.snapshot()
    assert snap["repro_lat_seconds_count"] == 4.0
    reg.reset()
    assert reg.counter("repro_requests_total").value == 0.0
    assert reg.names() == sorted(reg.names())


# ---------------------------------------------------------------------------
# engine integration: no-op guarantee + parity + valid traces


@pytest.mark.parametrize("mode", ["mono-dense", "mono-paged",
                                  "plan-dense", "plan-paged"])
def test_tracing_is_noop_off_and_parity_preserving_on(setup, mode, tmp_path):
    cfg, model, params = setup
    kw = {}
    if mode.startswith("plan"):
        kw["plan"] = _plan(cfg)
    if mode.endswith("paged"):
        kw.update(paged=True, page_size=4)

    before = trace_mod.RECORDS_TOTAL
    gold = _drive(ServingEngine(model, params, slots=2, max_seq=48, **kw))
    assert trace_mod.RECORDS_TOTAL == before, (
        "trace=None engine pushed trace records on the hot path")

    eng = ServingEngine(model, params, slots=2, max_seq=48,
                        trace=TraceConfig(), **kw)
    assert _drive(eng) == gold, f"{mode}: traced streams diverged"
    assert trace_mod.RECORDS_TOTAL > before

    obj = to_perfetto(eng._tr)
    prefill_name = "prefill_chunk" if "plan" in mode else "prefill"
    assert not validate_perfetto(
        obj, require_names=(prefill_name, "decode", "admit", "retire",
                            "submit"))
    path = tmp_path / "t.json"
    write_trace(eng._tr, str(path))
    assert json.loads(path.read_text())["traceEvents"]


def test_trace_spans_carry_stage_replica_and_request_tags(setup):
    cfg, model, params = setup
    eng = ServingEngine(model, params, slots=2, max_seq=48,
                        plan=_plan(cfg), paged=True, page_size=4, trace=True)
    _drive(eng)
    recs = eng._tr.records()
    chunk_tracks = {r[1] for r in recs if r[0] == "X" and
                    r[2] == "prefill_chunk"}
    assert chunk_tracks and all(t[0] == "stage" for t in chunk_tracks)
    dec_tracks = {r[1] for r in recs if r[0] == "X" and r[2] == "decode"}
    assert dec_tracks == {("replica", 0), ("replica", 1)}
    admits = [r for r in recs if r[0] == "X" and r[2] == "admit"]
    assert admits and all(r[6] == r[5]["uid"] for r in admits)  # flow id
    util = utilization_from_trace(eng._tr)
    assert util["window_s"] > 0
    assert set(util["replica_busy_frac"]) == {0, 1}


def test_utilization_stats_present_and_bounded(setup):
    cfg, model, params = setup
    eng = ServingEngine(model, params, slots=2, max_seq=48,
                        plan=_plan(cfg), paged=True, page_size=4)
    _drive(eng)
    u = eng.stats()["utilization"]
    assert u["pipeline_ticks"] > 0
    assert set(u["stage_bubble_frac"]) == {0, 1}
    assert all(0.0 <= v <= 1.0 for v in u["stage_bubble_frac"].values())
    assert set(u["replica_occupancy"]) == {0, 1}
    assert all(0.0 < v <= 1.0 for v in u["replica_occupancy"].values())
    assert 0.0 <= u["replica_load_spread"] <= 1.0
    assert 0.0 <= u["spec_acceptance_rate"] <= 1.0
    assert 0.0 <= u["prefix_hit_rate"] <= 1.0
    # mono window: replica 0 spans all slots, no pipeline ticks
    mono = ServingEngine(model, params, slots=2, max_seq=48)
    _drive(mono)
    mu = mono.stats()["utilization"]
    assert mu["pipeline_ticks"] == 0 and mu["stage_bubble_frac"] == {}
    assert set(mu["replica_occupancy"]) == {0}


def test_replan_decision_events_carry_scored_candidates(setup):
    cfg, model, params = setup
    plan = _plan(cfg)
    eng = ServingEngine(
        model, params, slots=2, max_seq=48, plan=plan, paged=True,
        page_size=4, trace=True,
        adapt=AdaptiveConfig(plans=[None, plan], interval_ticks=2,
                             cooldown_ticks=2, window_s=5.0, measure=False))
    _drive(eng, n=4, new_tokens=6)
    decisions = [r for r in eng._tr.records()
                 if r[0] == "I" and r[2] == "replan_decision"]
    assert decisions, "no replan_decision instants traced"
    scored = [r for r in decisions if r[4]["scores"]]
    assert scored, "no decision carried candidate scores"
    for label, score in scored[0][4]["scores"]:
        assert isinstance(label, str) and isinstance(score, float)
    assert all("decision" in r[4] for r in decisions)


def test_traffic_snapshot_is_typed_and_none_when_idle(setup):
    cfg, model, params = setup
    eng = ServingEngine(model, params, slots=2, max_seq=48)
    assert eng.traffic_snapshot(1.0) is None       # no traffic yet
    _drive(eng)
    sig = eng.traffic_snapshot(60.0, slo_ttft_s=1.0, slo_tpot_s=1.0)
    assert isinstance(sig, TrafficSnapshot)
    assert sig.lam > 0 and sig.avg_prompt > 0 and sig.avg_new > 0
    assert sig.window_s == 60.0
    assert sig.queue_len == 0 and sig.active == 0
    with pytest.raises(Exception):
        sig.lam = 1.0                              # frozen dataclass


def test_export_metrics_and_write_paths(setup, tmp_path):
    cfg, model, params = setup
    eng = ServingEngine(model, params, slots=2, max_seq=48,
                        plan=_plan(cfg), paged=True, page_size=4)
    _drive(eng)
    reg = eng.export_metrics()
    names = set(reg.names())
    assert {"repro_ttft_seconds", "repro_tpot_seconds",
            "repro_requests_total", "repro_tokens_generated_total",
            "repro_throughput_tok_s", "repro_phase_seconds",
            "repro_stage_bubble_frac", "repro_replica_occupancy",
            "repro_replans_total"} <= names
    # folding is idempotent: gauges are set, not accrued
    a = eng.export_metrics().snapshot()
    b = eng.export_metrics().snapshot()
    assert a == b
    assert a["repro_requests_total"] == 3.0
    p = tmp_path / "m.prom"
    write_metrics(reg, str(p))
    txt = p.read_text()
    assert txt.endswith("\n")
    assert "repro_ttft_seconds_bucket" in txt
    # write_trace refuses on an untraced engine
    with pytest.raises(ValueError):
        eng.write_trace(str(tmp_path / "t.json"))
