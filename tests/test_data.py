"""Data pipeline: determinism + step addressability (restart support)."""
import numpy as np

from repro.configs import REGISTRY, ShapeConfig, reduced
from repro.data import SyntheticLM


def test_deterministic():
    cfg = reduced(REGISTRY["yi-6b"])
    shp = ShapeConfig("t", 64, 4, "train")
    a = SyntheticLM(cfg, shp, seed=3).batch_at(5)
    b = SyntheticLM(cfg, shp, seed=3).batch_at(5)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_steps_differ_and_seeds_differ():
    cfg = reduced(REGISTRY["yi-6b"])
    shp = ShapeConfig("t", 64, 4, "train")
    d = SyntheticLM(cfg, shp, seed=3)
    assert not np.array_equal(d.batch_at(0)["tokens"],
                              d.batch_at(1)["tokens"])
    d2 = SyntheticLM(cfg, shp, seed=4)
    assert not np.array_equal(d.batch_at(0)["tokens"],
                              d2.batch_at(0)["tokens"])


def test_iterator_matches_batch_at():
    cfg = reduced(REGISTRY["yi-6b"])
    shp = ShapeConfig("t", 32, 2, "train")
    d = SyntheticLM(cfg, shp, seed=0, start_step=3)
    it = iter(d)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], d.batch_at(3)["tokens"])


def test_labels_shifted_from_tokens():
    cfg = reduced(REGISTRY["yi-6b"])
    shp = ShapeConfig("t", 32, 2, "train")
    b = SyntheticLM(cfg, shp).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_families_produce_right_keys():
    for arch, keys in [
        ("yi-6b", {"tokens", "labels"}),
        ("qwen2-vl-72b", {"embeds", "labels", "positions"}),
        ("whisper-base", {"enc_embeds", "dec_tokens", "labels"}),
        ("deit-t", {"embeds", "labels"}),
    ]:
        cfg = reduced(REGISTRY[arch])
        b = SyntheticLM(cfg, ShapeConfig("t", 32, 2, "train")).batch_at(0)
        assert set(b) == keys, arch
        for v in b.values():
            assert np.all(np.isfinite(v))
