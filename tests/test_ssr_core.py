"""SSR core: cost model, scheduler, EA, Pareto — unit + property tests."""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False

from repro.configs import SHAPES, get_config
from repro.core import (AccConfig, Features, TPU_V5E, build_graph,
                        contiguous_assignment, evolutionary_search,
                        exhaustive_search, mxu_efficiency, node_time,
                        pareto_front, sequential_assignment, simulate,
                        spatial_assignment, ssr_dse)
from repro.core.customize import (_compatible, count_design_points,
                                  customize_accs)
from repro.core.ea import _renumber
from repro.core.pareto import DesignPoint


def graph_of(arch="yi-6b", shape="train_4k"):
    return build_graph(get_config(arch), SHAPES[shape])


# ---------------------------------------------------------------------------
# graph IR
# ---------------------------------------------------------------------------

def test_graph_structure():
    g = graph_of()
    cfg = get_config("yi-6b")
    assert len(g.nodes) == cfg.num_layers + 2        # embed + blocks + head
    assert g.nodes[0].kind == "embed"
    assert g.nodes[-1].kind == "head"
    for n in g.nodes[1:]:
        assert n.deps, n.name
    assert g.total_mm_flops > 0


def test_graph_flops_magnitude():
    """Train FLOPs ≈ 6·N·D within 25% for a dense model (sanity anchor)."""
    g = graph_of("yi-6b", "train_4k")
    n_params = 6.05e9
    tokens = 256 * 4096
    expected = 6 * n_params * tokens
    assert 0.75 * expected < g.total_mm_flops < 1.35 * expected


def test_decode_graph_much_smaller():
    gd = graph_of("yi-6b", "decode_32k")
    gt = graph_of("yi-6b", "train_4k")
    assert gd.total_mm_flops < gt.total_mm_flops / 100


def test_moe_graph_counts_active_flops():
    """MoE FLOPs must scale with top-k·capacity, not num_experts."""
    g = graph_of("qwen2-moe-a2.7b", "train_4k")
    cfg = get_config("qwen2-moe-a2.7b")
    dense_equiv = 6 * 14e9 * 256 * 4096     # if all 60 experts were active
    assert g.total_mm_flops < dense_equiv   # far below all-expert compute


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_mxu_efficiency_bounds():
    assert 0 < mxu_efficiency(1, 1, 1) <= 0.95
    assert mxu_efficiency(128, 128, 128) == pytest.approx(0.95)
    assert mxu_efficiency(64, 128, 128) == pytest.approx(0.95 * 0.5)


def test_node_time_scales_with_chips():
    g = graph_of()
    n = g.nodes[5]
    t1 = node_time(n, AccConfig(16, 16, 1), train=True)["total"]
    t2 = node_time(n, AccConfig(256, 256, 1), train=True)["total"]
    assert t2 < t1


def test_fine_grained_pipeline_reduces_time():
    g = graph_of()
    n = g.nodes[5]
    acc = AccConfig(64, 16, 4)
    on = node_time(n, acc, train=True, feats=Features())["total"]
    off = node_time(n, acc, train=True,
                    feats=Features(fine_grained_pipeline=False))["total"]
    assert on <= off


# ---------------------------------------------------------------------------
# scheduler invariants
# ---------------------------------------------------------------------------

def test_sequential_latency_le_makespan():
    g = graph_of()
    a = sequential_assignment(g, 256)
    r = simulate(g, a, 4)
    assert r.latency <= r.makespan + 1e-12
    assert r.throughput_flops > 0


def test_spatial_improves_with_batches():
    """More pipelined batches -> higher spatial throughput (Fig 1(b))."""
    g = graph_of()
    a = spatial_assignment(g, 256, max_accs=8)
    t1 = simulate(g, a, 1, batch_frac=0.125).throughput_flops
    t8 = simulate(g, a, 8, batch_frac=0.125).throughput_flops
    assert t8 > t1


def test_dependencies_respected():
    """Makespan of 1 batch on a spatial map >= sum of critical-path durs."""
    g = graph_of()
    a = spatial_assignment(g, 256, max_accs=4)
    r = simulate(g, a, 1)
    from repro.core.costmodel import node_time as nt
    crit = sum(nt(n, a.accs[a.acc_of[n.idx]], train=g.train)["total"]
               for n in g.nodes)
    assert r.latency >= crit * 0.999


def test_simulate_matches_costmodel_sequential():
    """Cost-model/scheduler consistency: a single-acc sequential
    assignment has no pipelining and no inter-acc transfers, so
    ``simulate``'s timings must equal the plain sum of ``node_time``
    totals — ``core/assignment.py`` and ``core/costmodel.py`` cannot
    drift apart silently."""
    from repro.core.costmodel import node_time as nt
    g = graph_of()
    a = sequential_assignment(g, 256)
    for nb in (1, 4):
        for feats in (Features(), Features(fine_grained_pipeline=False)):
            r = simulate(g, a, nb, feats=feats)
            frac = 1.0 / nb
            per_batch = sum(
                nt(n, a.accs[0], batch_frac=frac, train=g.train,
                   feats=feats)["total"] for n in g.nodes)
            assert r.latency == pytest.approx(per_batch, rel=1e-9)
            assert r.makespan == pytest.approx(nb * per_batch, rel=1e-9)
            assert r.per_acc_busy[0] == pytest.approx(nb * per_batch,
                                                      rel=1e-9)


def test_simulate_matches_costmodel_fixed_config_platform():
    """Same consistency on a fixed-config (frozen array) platform: the
    scheduler must use the acc's frozen ref dims, i.e. agree with
    ``stage_time`` (which prices exactly one acc's node list)."""
    from benchmarks.common import BOARD_UNITS, VCK190_UNIT
    from repro.core.costmodel import stage_time
    g = graph_of("yi-6b", "prefill_32k")
    a = sequential_assignment(g, BOARD_UNITS)
    r = simulate(g, a, 1, hw=VCK190_UNIT)
    expected = stage_time(list(g.nodes), a.accs[0], g, VCK190_UNIT)
    assert r.latency == pytest.approx(expected, rel=1e-9)


def test_onchip_forwarding_ablation():
    """Paper §5.2.6 feature (1): disabling forwarding inflates latency."""
    g = graph_of()
    a = spatial_assignment(g, 256, max_accs=8)
    on = simulate(g, a, 4, feats=Features(onchip_forwarding=True))
    off = simulate(g, a, 4, feats=Features(onchip_forwarding=False))
    assert off.latency > on.latency


# ---------------------------------------------------------------------------
# customization (Algorithm 2)
# ---------------------------------------------------------------------------

def test_customize_respects_force_partition():
    g = graph_of()
    n_acc = 4
    a = contiguous_assignment(g, n_acc, 256)
    alloc = [acc.chips for acc in a.accs]
    accs = customize_accs(g, a.acc_of, alloc,
                          feats=Features(inter_acc_aware=True))
    # every communicating pair must be divisibility-compatible
    for i, n in enumerate(g.nodes):
        for d in n.deps:
            ai, ad = a.acc_of[i], a.acc_of[d]
            if ai != ad:
                assert _compatible(accs[ai], accs[ad]), (ai, ad)


def test_customize_dp_bounded_by_batch():
    g = build_graph(get_config("xlstm-125m"), SHAPES["long_500k"])  # B=1
    a = contiguous_assignment(g, 2, 256)
    accs = customize_accs(g, a.acc_of, [acc.chips for acc in a.accs])
    for acc in accs:
        assert acc.dp == 1          # batch=1 cannot data-parallelize


def test_design_space_counting():
    assert count_design_points([4, 4]) == 9   # divisors(4)=3 pairs each


# ---------------------------------------------------------------------------
# EA (Algorithm 1)
# ---------------------------------------------------------------------------

def test_renumber_canonical():
    assert _renumber((2, 2, 0, 1)) == (0, 0, 1, 2)
    assert _renumber((0, 1, 2)) == (0, 1, 2)


def test_ea_meets_latency_constraint():
    g = graph_of("yi-6b", "prefill_32k")
    seq = sequential_assignment(g, 256)
    base = simulate(g, seq, 1)
    res = evolutionary_search(g, 256, lat_cons=base.latency * 2.0,
                              n_acc=4, n_batches=2, n_pop=6, n_child=6,
                              n_iter=3, seed=0)
    assert res.latency <= base.latency * 2.0
    assert res.throughput > 0


def test_ea_beats_or_matches_worst_random():
    g = graph_of()
    res = evolutionary_search(g, 256, n_acc=4, n_batches=4, n_pop=8,
                              n_child=8, n_iter=4, seed=1)
    # must at least match a naive fully-spatial split at same batch count
    spa = spatial_assignment(g, 256, max_accs=4)
    worst = simulate(g, spa, 4)
    assert res.throughput >= worst.throughput_tops() * 0.5


def test_exhaustive_finds_feasible():
    g = graph_of("whisper-base", "prefill_32k")
    res = exhaustive_search(g, 256, n_acc=2, n_batches=2, max_evals=40)
    assert res.throughput > 0
    assert res.evaluations <= 40


# ---------------------------------------------------------------------------
# pareto
# ---------------------------------------------------------------------------

def test_pareto_front_nondominated():
    pts = [DesignPoint("s", 1, 1, lat, thr) for lat, thr in
           [(1, 10), (2, 20), (0.5, 5), (2, 15), (3, 20.0), (0.5, 6)]]
    front = pareto_front(pts)
    for p in front:
        for q in pts:
            assert not (q.latency < p.latency and
                        q.throughput_tops >= p.throughput_tops)
            assert not (q.latency <= p.latency and
                        q.throughput_tops > p.throughput_tops)


if HAVE_HYP:
    @given(st.integers(1, 4096), st.integers(1, 4096), st.integers(1, 4096))
    @settings(max_examples=50, deadline=None)
    def test_mxu_efficiency_in_unit_interval(m, k, n):
        e = mxu_efficiency(m, k, n)
        assert 0 < e <= 0.95

    @given(st.lists(st.tuples(st.floats(0.01, 100), st.floats(0.01, 100)),
                    min_size=1, max_size=30))
    @settings(max_examples=50, deadline=None)
    def test_pareto_property(pairs):
        pts = [DesignPoint("x", 1, 1, l, t) for l, t in pairs]
        front = pareto_front(pts)
        assert front, "front never empty"
        # front sorted by latency and throughput non-decreasing along it
        lats = [p.latency for p in front]
        assert lats == sorted(lats)
        thr = [p.throughput_tops for p in front]
        assert all(thr[i] <= thr[i + 1] + 1e-12 for i in range(len(thr) - 1))

    @given(st.integers(2, 64), st.integers(1, 6), st.integers(1, 8))
    @settings(max_examples=25, deadline=None)
    def test_renumber_idempotent(n, k, seed):
        import random
        rng = random.Random(seed)
        g = tuple(rng.randrange(k) for _ in range(n))
        r = _renumber(g)
        assert _renumber(r) == r
        assert len(set(r)) == len(set(g))
        assert max(r) == len(set(g)) - 1
