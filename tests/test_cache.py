"""Paged KV-cache subsystem: block pool / block table / prefix sharing /
copy-on-write / LRU units, the paged scatter + page-copy device helpers,
and the scatter/slice edge cases of the dense cache helpers."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.cache import BlockPool, PagedCacheManager, PoolExhausted
from repro.cache.paged import _ROOT
from repro.configs import REGISTRY, reduced
from repro.models import build_model
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# BlockPool
# ---------------------------------------------------------------------------

def test_pool_allocate_release_roundtrip():
    pool = BlockPool(4, page_size=2)
    blks = [pool.allocate() for _ in range(4)]
    assert sorted(blks) == [0, 1, 2, 3]
    assert pool.blocks_in_use == 4 and pool.blocks_free == 0
    with pytest.raises(PoolExhausted):
        pool.allocate()
    for b in blks:
        pool.release(b)
    assert pool.blocks_in_use == 0 and pool.blocks_free == 4
    assert pool.blocks_cached == 0        # unregistered blocks free outright


def test_pool_registered_blocks_park_in_lru_and_evict_oldest_first():
    pool = BlockPool(3, page_size=2)
    a, b = pool.allocate(), pool.allocate()
    pool.register(a, _ROOT, [1, 2])
    pool.register(b, pool.hash_of[a], [3, 4])
    pool.release(a)
    pool.release(b)
    assert pool.blocks_cached == 2 and pool.blocks_free == 1
    # drain: free block first, then LRU evictions oldest-release first
    assert pool.allocate() == 2
    assert pool.allocate() == a           # evicted + unregistered
    assert pool.evictions == 1
    assert a not in pool.hash_of
    assert b in pool.hash_of              # newer entry survives


def test_pool_register_collision_keeps_first_writer():
    pool = BlockPool(2, page_size=2)
    a, b = pool.allocate(), pool.allocate()
    assert pool.register(a, _ROOT, [7, 8])
    assert not pool.register(b, _ROOT, [7, 8])
    assert pool.registry[pool.hash_of[a]] == a
    assert b not in pool.hash_of          # stays private and writable
    assert pool.writable(b) and not pool.writable(a)


def test_pool_retain_revives_lru_block():
    pool = BlockPool(2, page_size=2)
    a = pool.allocate()
    pool.register(a, _ROOT, [1, 2])
    pool.release(a)
    assert a in pool.lru
    pool.retain(a)
    assert a not in pool.lru and pool.refcount[a] == 1
    pool.allocate()                       # only the other block remains
    with pytest.raises(PoolExhausted):    # the revived block is not
        pool.allocate()                   # evictable while referenced


# ---------------------------------------------------------------------------
# PagedCacheManager: admission / sharing / COW / release
# ---------------------------------------------------------------------------

def _mgr(slots=2, max_seq=16, page=4, blocks=8):
    return PagedCacheManager(slots, max_seq, page, blocks)


def test_manager_rejects_non_dividing_page_size():
    with pytest.raises(ValueError, match="multiple of"):
        PagedCacheManager(1, max_seq=10, page_size=4, num_blocks=4)


def test_admit_allocates_prompt_blocks_and_commit_registers_full_ones():
    m = _mgr()
    ap = m.admit(0, np.arange(1, 7))      # 6 tokens: 1 full + 1 partial
    assert ap is not None and ap.n_write == 2
    # the table row maps only at commit (a reserved slot mid-prefill must
    # ride decode with an unmapped row so stale-position writes drop)
    assert m.tables[0].n_mapped == 0
    assert not m.pool.registry            # nothing published before commit
    m.commit(0)
    assert m.tables[0].n_mapped == 2
    assert len(m.pool.registry) == 1      # only the FULL block registers
    # padded write vectors: pad entries point one past the pool (dropped)
    assert list(ap.write_logical[:2]) == [0, 1]
    assert all(p == m.pool.num_blocks for p in ap.write_phys[ap.n_write:])


def test_admit_shares_full_prefix_blocks_refcounted():
    m = _mgr()
    m.admit(0, np.arange(1, 9))           # 8 tokens = 2 full blocks
    m.commit(0)
    ap = m.admit(1, np.arange(1, 9))      # identical prompt
    m.commit(1)
    assert ap is not None and ap.n_write == 0
    assert np.array_equal(m.tables[0].blocks[:2], m.tables[1].blocks[:2])
    for blk in m.tables[0].blocks[:2]:
        assert m.pool.refcount[blk] == 2
    assert ap.shared_blocks == tuple(int(b) for b in m.tables[0].blocks[:2])


def test_partial_tail_share_then_copy_on_write():
    m = _mgr()
    m.admit(0, np.arange(1, 9))           # blocks [0..3], [4..7] tokens 1..8
    m.commit(0)
    ap = m.admit(1, np.arange(1, 7))      # 6 tokens: full match + tail [5,6]
    m.commit(1)
    assert ap is not None and ap.n_write == 0
    tail = int(m.tables[1].blocks[1])
    assert tail == int(m.tables[0].blocks[1])
    assert m.pool.refcount[tail] == 2
    # first decode write at pos 6 diverges from the registered content
    cow = m.prepare_decode(1, 6)
    assert cow is not None and cow[0] == tail
    assert m.tables[1].blocks[1] == cow[1] != tail
    assert m.pool.refcount[tail] == 1 and m.pool.cow_copies == 1


def test_decode_allocates_at_page_boundary_and_registers_filled_blocks():
    m = _mgr()
    m.admit(0, np.arange(1, 5))           # exactly one full block
    m.commit(0)
    assert m.prepare_decode(0, 4) is None     # new boundary: fresh block
    assert m.tables[0].n_mapped == 2
    before = len(m.pool.registry)
    for pos, tok in zip(range(4, 8), [9, 9, 9, 9]):
        m.note_written(0, tok, pos)
    assert len(m.pool.registry) == before + 1  # decode-filled block published
    assert m.prepare_decode(0, 8) is None      # next boundary allocates again
    assert m.tables[0].n_mapped == 3


def test_admit_defers_when_pool_cannot_supply_blocks():
    m = _mgr(slots=2, max_seq=16, page=4, blocks=2)
    assert m.admit(0, np.arange(1, 8)) is not None     # needs both blocks
    assert m.admit(1, np.arange(20, 26)) is None       # no state change
    assert m.tables[1].n_mapped == 0
    m.release_slot(0)
    assert m.admit(1, np.arange(20, 26)) is not None   # blocks came back


def test_never_fits_raises_even_when_prefix_is_shared():
    """Regression: feasibility must count the retained shared blocks —
    a shared + fresh footprint exceeding the pool would otherwise defer
    forever (livelocking the FIFO head) instead of raising."""
    m = _mgr(slots=2, max_seq=32, page=4, blocks=4)
    m.admit(0, np.arange(1, 9), max_new_tokens=0)   # 2 full blocks
    m.commit(0)
    m.release_slot(0)                               # parked in the LRU
    with pytest.raises(PoolExhausted, match="num_blocks"):
        # same prefix: 2 shared + 3 growth = 5 > 4 can never fit
        m.admit(1, np.arange(1, 9), max_new_tokens=9)


def test_deferred_admission_does_not_inflate_reuse_counters():
    """Regression: a deferred (retried) admission must count its registry
    lookups once, on the attempt that admits — not once per retry —
    or the reported reuse_hit_rate drifts toward the deferred request."""
    m = _mgr(slots=2, max_seq=16, page=4, blocks=4)
    m.admit(0, np.arange(1, 9), max_new_tokens=4)   # holds 2+1 blocks
    m.commit(0)
    for _ in range(5):                              # same prefix, no room
        assert m.admit(1, np.arange(1, 9), max_new_tokens=8) is None
    assert m.pool.prefix_queries == 1 and m.pool.prefix_hits == 0
    m.release_slot(0)
    assert m.admit(1, np.arange(1, 9), max_new_tokens=8) is not None
    assert m.pool.prefix_queries == 3 and m.pool.prefix_hits == 2


def test_never_fits_raise_restores_reuse_counters():
    """Regression: the PoolExhausted raise is still 'no admission
    happened' — its registry lookups must not count, exactly like the
    deferral path, or a never-fits request permanently skews the
    reported reuse_hit_rate."""
    m = _mgr(slots=2, max_seq=32, page=4, blocks=4)
    m.admit(0, np.arange(1, 9), max_new_tokens=0)   # 2 full blocks
    m.commit(0)
    m.release_slot(0)                               # parked in the LRU
    q0, h0 = m.pool.prefix_queries, m.pool.prefix_hits
    with pytest.raises(PoolExhausted):
        # same prefix: the lookup HITS both blocks before the raise
        m.admit(1, np.arange(1, 9), max_new_tokens=9)
    assert m.pool.prefix_queries == q0 and m.pool.prefix_hits == h0


def test_rollback_releases_rejected_tail_blocks():
    """Speculative reject path: rollback truncates the chain to the
    accepted position, releases blocks wholly past it back to the pool,
    and returns them to the slot's growth reservation — decode can
    regrow over the same positions."""
    m = _mgr(slots=1, max_seq=16, page=4, blocks=8)
    m.admit(0, np.asarray([1, 2]), max_new_tokens=10)
    m.commit(0)
    # a verify window writes positions 2..5 (current token + 3 drafts):
    # position 4 crosses into a fresh boundary block
    for pos in range(2, 6):
        m.prepare_decode(0, pos)
    assert m.tables[0].n_mapped == 2
    used = m.pool.blocks_in_use
    reserved = m.tables[0].reserved
    m.note_written(0, 7, 2)                  # one accepted input token
    m.rollback(0, 3)                         # tail 3..5 rejected
    tb = m.tables[0]
    assert tb.chain == [1, 2, 7]
    assert int(tb.blocks[0]) >= 0            # accepted block kept
    assert int(tb.blocks[1]) == -1           # rejected boundary block freed
    assert m.pool.blocks_in_use == used - 1
    assert tb.reserved == reserved + 1       # growth returned to reserve
    assert tb.hashes == []                   # no full block in the chain
    # decode regrows over the rolled-back positions
    for pos, tok in zip(range(3, 6), [8, 9, 10]):
        m.prepare_decode(0, pos)
        m.note_written(0, tok, pos)
    assert int(m.tables[0].blocks[1]) >= 0
    assert tb.chain == [1, 2, 7, 8, 9, 10]
    assert len(tb.hashes) == 1               # block 0 filled and hashed


def test_rollback_never_releases_accepted_or_shared_blocks():
    """Rollback keeps every block at or below the accepted position —
    including registered prompt blocks another slot still shares."""
    m = _mgr(slots=2, max_seq=16, page=4, blocks=8)
    m.admit(0, np.arange(1, 9))              # 2 full registered blocks
    m.commit(0)
    m.admit(1, np.arange(1, 9))              # shares both
    m.commit(1)
    shared = [int(b) for b in m.tables[1].blocks[:2]]
    # slot 1 runs a verify window at 8..10 and rejects everything past 9
    for pos in range(8, 11):
        m.prepare_decode(1, pos)
    m.note_written(1, 30, 8)
    m.rollback(1, 9)
    assert [int(b) for b in m.tables[1].blocks[:2]] == shared
    assert all(m.pool.refcount[b] == 2 for b in shared)
    assert int(m.tables[1].blocks[2]) >= 0   # holds accepted position 8
    assert m.tables[1].chain == list(range(1, 9)) + [30]


def test_admit_reuse_compute_reports_suffix_and_tables():
    """A warm admission with reuse_compute=True skips the matched prefix
    (keeping the last prompt token — its hidden state makes the first
    logits) and describes the device work through two tables: the gather
    table maps EVERY block, the write table only the fresh ones."""
    m = _mgr()
    NB = m.pool.num_blocks
    ap0 = m.admit(0, np.arange(1, 9))         # cold: 2 full blocks
    assert ap0.reused_tokens == 0             # nothing warm yet
    m.commit(0)
    ap = m.admit(1, np.arange(1, 9), reuse_compute=True)
    m.commit(1)
    assert ap.reused_tokens == 7              # 8 matched, capped at L-1
    assert ap.n_write == 0
    assert list(ap.block_table[:2]) == list(m.tables[0].blocks[:2])
    assert all(b == NB for b in ap.block_table[2:])   # unmapped: sentinel
    assert all(b == NB for b in ap.write_table)       # nothing fresh
    assert m.pool.prefill_admissions == 2
    assert m.pool.prefill_compute_hits == 1
    assert m.pool.reused_prefill_tokens == 7
    assert m.pool.suffix_prefill_tokens == 8 + 1
    st = m.stats()
    assert st["prefill_hit_rate"] == 0.5 and st["prefix_cache"]


def test_write_table_sentinels_protect_shared_blocks():
    """Partial warm match: fresh suffix blocks appear in the write table
    at their logical positions; the shared prefix blocks carry the
    sentinel so the suffix prefill's page writes drop on them."""
    m = _mgr()
    NB = m.pool.num_blocks
    m.admit(0, np.arange(1, 5))               # 1 full block [1..4]
    m.commit(0)
    suffix_prompt = np.concatenate([np.arange(1, 5), np.arange(40, 46)])
    ap = m.admit(1, suffix_prompt, reuse_compute=True)
    assert ap.reused_tokens == 4 and ap.n_write == 2
    assert ap.write_table[0] == NB            # shared block: write drops
    assert ap.write_table[1] != NB and ap.write_table[2] != NB
    assert all(ap.block_table[:3] < NB)       # gather maps all three


def test_commit_chunk_publishes_full_blocks_incrementally():
    """Chunked prefill feeds the compute cache mid-prompt: each chunk
    that lands publishes the full blocks it completed, while the table
    row stays unmapped until the final commit."""
    m = _mgr()
    m.admit(0, np.arange(1, 11))              # 10 tokens: 2 full + 1 tail
    m.commit_chunk(0, 4)                      # first chunk landed
    assert len(m.pool.registry) == 1
    assert m.tables[0].n_mapped == 0          # row mapping still deferred
    m.commit_chunk(0, 6)                      # mid-block: nothing new
    assert len(m.pool.registry) == 1
    m.commit_chunk(0, 8)
    assert len(m.pool.registry) == 2
    # a second request can hit blocks of the still-mid-prefill prompt
    ap = m.admit(1, np.arange(1, 9), reuse_compute=True)
    assert ap.reused_tokens == 7
    m.commit(0)
    assert m.tables[0].n_mapped == 3
    assert len(m.pool.registry) == 2          # chunks already published all


def test_prefix_cache_off_disables_sharing_and_parking():
    """prefix_cache=False: no registry lookups, no publication, and
    release frees blocks outright (nothing parks in the LRU)."""
    m = PagedCacheManager(2, 16, 4, 8, prefix_cache=False)
    ap0 = m.admit(0, np.arange(1, 9), reuse_compute=True)
    m.commit(0)
    assert not m.pool.registry                # commit publishes nothing
    assert ap0.reused_tokens == 0
    ap = m.admit(1, np.arange(1, 9), reuse_compute=True)
    m.commit(1)
    assert ap.n_write == 2                    # identical prompt: no share
    assert ap.reused_tokens == 0
    assert m.pool.prefix_queries == 0
    m.release_slot(0)
    m.release_slot(1)
    assert m.pool.blocks_cached == 0 and m.pool.blocks_free == 8
    assert not m.stats()["prefix_cache"]


def test_parked_compute_cache_evicts_under_pool_pressure():
    """Parked warm blocks are reclaimable capacity: a new admission that
    needs the whole pool evicts them oldest-first and still succeeds —
    the compute cache never wedges the pool."""
    m = _mgr(slots=2, max_seq=16, page=4, blocks=4)
    m.admit(0, np.arange(1, 13))              # 12 tokens = 3 full blocks
    m.commit(0)
    m.release_slot(0)                         # full blocks park in the LRU
    assert m.pool.blocks_cached == 3
    ap = m.admit(1, np.arange(50, 64))        # 14 tokens = 4 fresh blocks
    assert ap is not None and ap.n_write == 4
    assert m.pool.evictions >= 1              # parked blocks reclaimed
    assert m.pool.blocks_cached == 0
    # evicted entries left the registry: the old prefix no longer hits
    m.commit(1)
    m.release_slot(1)
    ap2 = m.admit(0, np.arange(1, 13), reuse_compute=True)
    assert ap2.reused_tokens == 0


def test_lookup_full_verifies_tokens_not_just_hash():
    """A registry hit must match stored content, so a chain-hash
    collision degrades to a miss instead of mapping foreign K/V."""
    pool = BlockPool(2, page_size=2)
    a = pool.allocate()
    pool.register(a, _ROOT, [1, 2])
    h = pool.hash_of[a]
    # same hash key, different content: force the collision directly
    _, hit = pool.lookup_full(_ROOT, [1, 2])
    assert hit == a
    pool.tokens_of[a] = np.asarray([9, 9], np.int32)   # simulate collision
    _, hit = pool.lookup_full(_ROOT, [1, 2])
    assert hit is None
    assert h in pool.registry                          # entry kept intact


def test_release_parks_registered_blocks_for_reuse():
    m = _mgr()
    m.admit(0, np.arange(1, 9))
    m.commit(0)
    m.release_slot(0)
    assert m.pool.blocks_in_use == 0 and m.pool.blocks_cached == 2
    ap = m.admit(1, np.arange(1, 9))      # retired prefix still reusable
    assert ap is not None and ap.n_write == 0
    assert m.pool.prefix_hits >= 2


# ---------------------------------------------------------------------------
# slot migration (re-plan / work stealing) + peak-tracker re-attach
# ---------------------------------------------------------------------------

def test_migrate_slot_hands_over_table_row_without_touching_pool():
    """Zero-copy slot migration: the whole move is a block-table row
    handoff — same physical blocks, same refcounts, no allocation, no
    release, no COW.  The destination row then decodes exactly as the
    source would have."""
    m = _mgr(slots=3)
    m.admit(0, np.arange(1, 10))          # 9 tokens: 2 full + 1 tail block
    m.commit(0)
    blocks = list(m.tables[0].blocks)
    chain = list(m.tables[0].chain)
    in_use = m.pool.blocks_in_use
    refs = list(m.pool.refcount)

    m.migrate_slot(0, 2)
    assert m.migrations == 1 and m.stats()["migrations"] == 1
    assert list(m.tables[2].blocks) == blocks
    assert m.tables[2].chain == chain
    assert m.tables[0].n_mapped == 0 and m.tables[0].chain == []
    assert all(b == -1 for b in m.tables[0].blocks)
    # the pool never noticed: no alloc/free/COW/refcount churn
    assert m.pool.blocks_in_use == in_use
    assert list(m.pool.refcount) == refs
    assert m.pool.cow_copies == 0 and m.pool.evictions == 0
    # decode continues seamlessly on the new row (pos 9 is mid-block 2)
    assert m.prepare_decode(2, 9) is None
    m.note_written(2, 99, 9)
    # the vacated source row is immediately admittable
    assert m.admit(0, np.arange(20, 26)) is not None

    m.migrate_slot(2, 2)                  # self-move is a no-op
    assert m.migrations == 1


def test_migrate_slot_refuses_occupied_or_pending_rows():
    m = _mgr(slots=3)
    m.admit(0, np.arange(1, 9))
    m.commit(0)
    m.admit(1, np.arange(20, 28))
    m.commit(1)
    with pytest.raises(AssertionError):   # destination row is occupied
        m.migrate_slot(0, 1)
    m.admit(2, np.arange(30, 36))         # slot 2 mid-prefill (uncommitted)
    with pytest.raises(AssertionError):   # pending slots must not move
        m.migrate_slot(2, 0)


def test_concurrent_peak_tracker_reattach_is_idempotent():
    """Regression: re-planning re-attaches the SURVIVING pool to the
    engine-lifetime tracker.  Pre-fix, attach() appended the pool again,
    so every subsequent note() summed it twice and the reported
    concurrent peak doubled."""
    from repro.cache import ConcurrentPeakTracker
    pool = BlockPool(8, page_size=2)
    tr = ConcurrentPeakTracker()
    tr.attach(pool)
    pool.allocate()
    tr.attach(pool)                       # a re-plan re-attaches
    pool.allocate()                       # 2 blocks in use
    assert len(tr.pools) == 1
    assert tr.peak == 2                   # pre-fix: 4 (pool counted twice)


# ---------------------------------------------------------------------------
# device helpers: paged scatter, page copy
# ---------------------------------------------------------------------------

def _paged_setup(arch="yi-6b", layers=1, slots=2, max_seq=16, page=4):
    cfg = reduced(REGISTRY[arch], layers=layers)
    model = build_model(cfg)
    nb = slots * (max_seq // page)
    full = model.init_paged_cache(slots, max_seq, page_size=page,
                                  num_blocks=nb)
    part = model.init_cache(1, max_seq)
    return cfg, model, full, part, nb


def test_scatter_prefill_part_scatters_dense_only_pool_passthrough():
    """Finishing a paged prefill has NO commit-time page copy: only the
    dense remainder (SSM state, rings) scatters into the slot row — the
    pool leaves pass through object-identical (their K/V was written in
    place through the write tables as the prefill ran)."""
    cfg = reduced(REGISTRY["jamba-1.5-large-398b"], layers=8)
    model = build_model(cfg)
    full = model.init_paged_cache(3, 16, page_size=4, num_blocks=12)
    part = T.make_prefill_part(cfg, 16)
    part = jax.tree.map(lambda x: jnp.ones_like(x), part)
    out = T.scatter_prefill_part(full, part, jnp.int32(1))
    for j, blk in enumerate(cfg.block_pattern):
        sub = out[f"b{j}"]
        if blk.mixer == "attn":
            assert sub is full[f"b{j}"]            # pool: untouched object
        else:
            leaf = np.asarray(jax.tree.leaves(sub)[0])
            assert np.all(leaf[:, 1] == 1.0)       # slot row landed
            assert np.all(leaf[:, 0] == 0.0)       # other slots untouched


def test_prefill_view_combine_split_roundtrip():
    """combine_prefill_parts / split_prefill_parts are exact inverses:
    paged blocks ride the pool leaves, dense blocks the batch-1 part."""
    cfg = reduced(REGISTRY["jamba-1.5-large-398b"], layers=8)
    model = build_model(cfg)
    full = model.init_paged_cache(2, 16, page_size=4, num_blocks=8)
    part = T.make_prefill_part(cfg, 16)
    view = T.combine_prefill_parts(full, part)
    paged2, part2 = T.split_prefill_parts(view, full)
    assert jax.tree.structure(paged2) == jax.tree.structure(full)
    assert jax.tree.structure(part2) == jax.tree.structure(part)
    for a, b in zip(jax.tree.leaves(paged2), jax.tree.leaves(full)):
        assert a is b
    for a, b in zip(jax.tree.leaves(part2), jax.tree.leaves(part)):
        assert a is b


def test_copy_cache_pages_copies_one_block_everywhere():
    cfg, model, full, part, nb = _paged_setup()
    full = jax.tree.map(lambda x: jnp.ones_like(x), full)
    full["b0"]["kv"]["k_pages"] = full["b0"]["kv"]["k_pages"].at[:, 3].set(7.0)
    out = T.copy_cache_pages(full, jnp.int32(3), jnp.int32(1))
    kp = np.asarray(out["b0"]["kv"]["k_pages"])
    assert kp[:, 1].min() == 7.0 and kp[:, 3].min() == 7.0
    assert kp[:, 0].max() == 1.0                       # others untouched


def test_make_paged_cache_layer_layout_mixed_family():
    """Global attention pages; local-window rings and SSM state stay dense
    (per-slot batch axis)."""
    cfg = reduced(REGISTRY["jamba-1.5-large-398b"], layers=8)
    model = build_model(cfg)
    cache = model.init_paged_cache(3, 16, page_size=4, num_blocks=12)
    mixers = [b.mixer for b in cfg.block_pattern]
    for j, mix in enumerate(mixers):
        sub = cache[f"b{j}"]
        if mix == "attn":
            assert set(sub["kv"]) == {"k_pages", "v_pages"}
            # num_blocks + 1: the pool carries a trailing sink page the
            # fused decode kernel parks unmapped-slot writes in
            assert sub["kv"]["k_pages"].shape[1:3] == (13, 4)
        else:
            assert "ssm_state" in sub
            leaf = jax.tree.leaves(sub["ssm_state"])[0]
            assert leaf.shape[1] == 3                  # slots axis


def test_has_paged_layers_gating():
    assert T.has_paged_layers(reduced(REGISTRY["yi-6b"], layers=1))
    assert T.has_paged_layers(reduced(REGISTRY["gemma2-9b"], layers=2))
    assert not T.has_paged_layers(reduced(REGISTRY["xlstm-125m"], layers=4))


# ---------------------------------------------------------------------------
# dense helper edge cases: scatter_cache_slot / slice_cache_groups
# ---------------------------------------------------------------------------

def _dense_cache(arch, layers, slots, max_seq=8):
    cfg = reduced(REGISTRY[arch], layers=layers)
    model = build_model(cfg)
    return cfg, model, model.init_cache(slots, max_seq)


@pytest.mark.parametrize("slot", [0, 3])
def test_scatter_cache_slot_first_and_last_slot(slot):
    cfg, model, full = _dense_cache("yi-6b", 1, slots=4)
    part = jax.tree.map(lambda x: jnp.ones_like(x),
                        model.init_cache(1, 8))
    out = T.scatter_cache_slot(full, part, jnp.int32(slot))

    def check(leaf):
        a = np.asarray(leaf)
        assert a[:, slot].min() == 1.0
        others = [s for s in range(4) if s != slot]
        assert abs(a[:, others]).max() == 0.0
    jax.tree.map(check, out)


def test_scatter_cache_slot_ssm_state_only_cache():
    """Pure-SSM caches (no KV leaves at all) ride the same scatter: the
    recurrent state lives on the same (groups, slots, ...) axes."""
    cfg, model, full = _dense_cache("xlstm-125m", 4, slots=3)
    assert not any("kv" in sub for sub in full.values())
    part = jax.tree.map(lambda x: 2.0 * jnp.ones_like(x),
                        model.init_cache(1, 8))
    out = T.scatter_cache_slot(full, part, jnp.int32(2))

    def check(leaf):
        a = np.asarray(leaf)
        assert a[:, 2].min() == 2.0 and abs(a[:, :2]).max() == 0.0
    jax.tree.map(check, out)


def test_slice_cache_groups_single_group_plan_and_bounds():
    """Single-group slices (the finest stage cut), the first/last group,
    and the merge/concat round-trip."""
    cfg, model, full = _dense_cache("yi-6b", 4, slots=2)
    G = cfg.num_groups
    assert G == 4
    full = jax.tree.map(
        lambda x: x + jnp.arange(G, dtype=x.dtype).reshape(
            (G,) + (1,) * (x.ndim - 1)), full)
    for g in (0, G - 1):
        sl = T.slice_cache_groups(full, g, 1)
        jax.tree.map(lambda l, g=g: np.testing.assert_array_equal(
            np.asarray(l), g), sl)
    # round-trip: slice each group, concat, compare to the original
    slices = [T.slice_cache_groups(full, g, 1) for g in range(G)]
    back = T.concat_cache_groups(slices)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), full, back)
    # merge writes back exactly one group range
    bumped = jax.tree.map(lambda l: l + 100.0, slices[2])
    merged = T.merge_cache_groups(full, bumped, 2)
    jax.tree.map(lambda l: np.testing.assert_array_equal(
        np.asarray(l)[2], 102.0), merged)
    jax.tree.map(lambda l, o: np.testing.assert_array_equal(
        np.asarray(l)[[0, 1, 3]], np.asarray(o)[[0, 1, 3]]), merged, full)


def test_slice_cache_groups_works_on_paged_leaves():
    """Paged caches keep the leading group axis, so plan stage slicing is
    layout-agnostic (the serving plan runtime relies on this)."""
    cfg = reduced(REGISTRY["yi-6b"], layers=4)
    model = build_model(cfg)
    cache = model.init_paged_cache(2, 16, page_size=4, num_blocks=8)
    sl = T.slice_cache_groups(cache, 1, 2)
    assert sl["b0"]["kv"]["k_pages"].shape[0] == 2
    assert sl["b0"]["kv"]["k_pages"].shape[1:3] == (9, 4)   # +1 sink page
