"""Serving engine: request completion, continuous batching, greedy decode
consistency."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduced
from repro.models import build_model
from repro.serving import Request, ServingEngine, make_serve_step


def setup():
    cfg = reduced(REGISTRY["yi-6b"], layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_engine_completes_requests():
    cfg, model, params = setup()
    eng = ServingEngine(model, params, slots=2, max_seq=48)
    for uid in range(4):
        eng.submit(Request(uid, np.arange(1, 4 + uid, dtype=np.int32), 6))
    done = eng.run()
    assert len(done) == 4
    for r in done:
        assert len(r.out_tokens) == 6
        assert r.t_done >= r.t_first >= r.t_submit


def test_greedy_decode_deterministic():
    cfg, model, params = setup()
    outs = []
    for _ in range(2):
        eng = ServingEngine(model, params, slots=1, max_seq=32)
        eng.submit(Request(0, np.array([5, 6, 7], np.int32), 5))
        done = eng.run()
        outs.append(tuple(done[0].out_tokens))
    assert outs[0] == outs[1]


def test_serve_step_greedy_matches_argmax():
    cfg, model, params = setup()
    step = jax.jit(make_serve_step(model))
    B, T = 2, 8
    toks = jnp.ones((B, T), jnp.int32)
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, 32))(
        params, {"tokens": toks})
    nxt, logits, cache = step(params, cache, jnp.ones((B, 1), jnp.int32),
                              jnp.int32(T))
    np.testing.assert_array_equal(
        np.asarray(nxt[:, 0]), np.asarray(jnp.argmax(logits[:, -1], -1)))


def test_engine_stats_ordering_and_occupancy():
    """t_submit <= t_first <= t_done per request; slot occupancy is a
    fraction of `slots` (never above 1); stats name the kernel path."""
    cfg, model, params = setup()
    eng = ServingEngine(model, params, slots=2, max_seq=48)
    for uid in range(5):
        eng.submit(Request(uid, np.arange(1, 4 + uid, dtype=np.int32), 4))
    eng.run()
    st = eng.stats()
    assert st["requests"] == 5
    assert st["gen_tokens"] == sum(len(r.out_tokens) for r in eng.done) == 20
    assert 0.0 < st["slot_occupancy"] <= 1.0
    assert st["throughput_tok_s"] > 0
    assert st["kernel_path"] == eng.kernel_path
    for r in eng.done:
        assert r.t_submit <= r.t_first <= r.t_done
        assert 0 <= r.slot < eng.slots
    assert len(st["ttft_s"]) == len(st["latency_s"]) == 5
    assert all(t >= 0 for t in st["ttft_s"])


def test_stats_report_cache_memory_utilization():
    """stats()["cache"] reports live vs reserved tokens for the dense
    layout, plus block-pool occupancy and prefix-reuse figures when
    paging is on."""
    cfg, model, params = setup()
    eng = ServingEngine(model, params, slots=2, max_seq=48)
    eng.submit(Request(0, np.arange(1, 6, dtype=np.int32), 4))
    eng.tick()                           # admit: 5 prompt tokens live
    st = eng.stats()["cache"]
    assert st["layout"] == "dense"
    assert st["reserved_tokens"] == 2 * 48
    assert st["live_tokens"] == 6        # prompt + the first decode write
    assert st["utilization"] == pytest.approx(6 / 96)
    eng.run()
    assert eng.stats()["cache"]["live_tokens"] == 0    # all retired

    paged = ServingEngine(model, params, slots=2, max_seq=48, paged=True,
                          page_size=4)
    p = np.arange(1, 9, dtype=np.int32)
    paged.submit(Request(0, p, 4))
    paged.submit(Request(1, p.copy(), 4))    # identical prompt: full reuse
    paged.run()
    st = paged.stats()["cache"]
    assert st["layout"] == "paged"
    assert st["page_size"] == 4 and st["num_blocks"] == 2 * (48 // 4)
    assert st["blocks_in_use"] == 0          # retired -> parked or freed
    assert st["prefix_hits"] >= 2            # both full blocks reused
    assert 0.0 < st["reuse_hit_rate"] <= 1.0
    assert st["peak_blocks_in_use"] >= 2
    assert st["effective_slots_gain"] >= 1.0


def test_stats_report_per_phase_host_timing():
    """stats() breaks the host wall-clock into admission / prefill /
    decode per tick; reset_stats() zeroes the window."""
    cfg, model, params = setup()
    eng = ServingEngine(model, params, slots=2, max_seq=48)
    eng.submit(Request(0, np.arange(1, 6, dtype=np.int32), 4))
    eng.run()
    st = eng.stats()
    assert st["ticks"] > 0
    pt = st["phase_time_s"]
    assert set(pt) == {"admission", "prefill", "decode", "replan",
                       "idle", "host_sync"}
    assert all(v >= 0.0 for v in pt.values())
    assert pt["prefill"] > 0.0 and pt["decode"] > 0.0
    # host_sync overlays the phase windows: every tick blocks on at
    # least the prefill first-token / decode readback, and the blocked
    # time can never exceed the phases it sits inside
    assert pt["host_sync"] > 0.0
    assert pt["host_sync"] <= pt["admission"] + pt["prefill"] + pt["decode"]
    eng.reset_stats()
    st = eng.stats()
    assert st["ticks"] == 0
    assert all(v == 0.0 for v in st["phase_time_s"].values())


def test_phase_time_partition_sums_to_wall_including_idle():
    """The partition buckets (admission / prefill / decode / replan /
    idle) tile the first-tick..last-tick wall window: idle charges the
    host time BETWEEN ticks, so the sum matches the window even when the
    caller sleeps mid-run.  host_sync is an overlay (accrues inside open
    phases) and stays outside the partition."""
    import time

    cfg, model, params = setup()
    eng = ServingEngine(model, params, slots=2, max_seq=48)
    eng.submit(Request(0, np.arange(1, 6, dtype=np.int32), 6))
    busy = True
    while busy:
        busy = eng.tick()
        time.sleep(0.002)            # caller-side gap -> idle bucket
    pt = eng.stats()["phase_time_s"]
    assert pt["idle"] > 0.0
    wall = eng._t_tick_end - eng._t_first_tick
    partition = (pt["admission"] + pt["prefill"] + pt["decode"]
                 + pt["replan"] + pt["idle"])
    assert partition == pytest.approx(wall, rel=0.02, abs=1e-4)
    eng.reset_stats()
    assert eng._t_first_tick is None and eng._t_tick_end is None
    assert eng.stats()["phase_time_s"]["idle"] == 0.0


def test_stats_repeat_calls_are_idempotent():
    """stats() is a pure snapshot: calling it twice in the same window
    returns identical payloads (no double-aggregation), including the
    nested cache and utilization sub-dicts, on dense and paged engines."""
    cfg, model, params = setup()
    for kw in ({}, {"paged": True, "page_size": 4}):
        eng = ServingEngine(model, params, slots=2, max_seq=48, **kw)
        for uid in range(3):
            eng.submit(Request(uid, np.arange(1, 6, dtype=np.int32), 4))
        eng.run()
        a, b = eng.stats(), eng.stats()
        assert a == b
        assert a["cache"] == b["cache"]
        assert a["utilization"] == b["utilization"]
        # a mutated copy must not leak back into the engine's counters
        a["cache"]["layout"] = "mutated"
        a["utilization"]["pipeline_ticks"] = -1
        c = eng.stats()
        assert c["cache"]["layout"] != "mutated"
        assert c == b


def test_warm_prefix_runs_suffix_only_and_stays_token_identical():
    """The tentpole contract end-to-end: a second request whose prompt
    extends a retired request's prefix reports a prefill-compute hit,
    prefills only the unmatched suffix, and still emits exactly the
    token stream of a cold engine."""
    cfg, model, params = setup()
    shared = np.arange(1, 9, dtype=np.int32)         # 2 full 4-token blocks
    warm_prompt = np.concatenate([shared, np.array([30, 31], np.int32)])

    cold = ServingEngine(model, params, slots=1, max_seq=48, paged=True,
                         page_size=4, prefill_bucket=4, prefix_cache=False)
    cold.submit(Request(0, warm_prompt.copy(), 6))
    gold = list(cold.run()[0].out_tokens)
    assert cold.stats()["cache"]["prefill_compute_hits"] == 0

    eng = ServingEngine(model, params, slots=1, max_seq=48, paged=True,
                        page_size=4, prefill_bucket=4)
    eng.submit(Request(0, shared.copy(), 4))         # seeds the registry
    eng.submit(Request(1, warm_prompt.copy(), 6))
    done = {r.uid: list(r.out_tokens) for r in eng.run()}
    assert done[1] == gold                           # token-identical
    st = eng.stats()["cache"]
    assert st["prefill_compute_hits"] == 1
    assert st["prefill_hit_rate"] == 0.5
    assert st["reused_prefill_tokens"] == 8          # both shared blocks
    # suffix-only: the warm admission prefilled 2 tokens (padded to the
    # bucket), not the 10-token prompt
    assert eng.prefill_token_counts[1] < len(warm_prompt)


def test_prefix_cache_flag_disables_reuse_end_to_end():
    """prefix_cache=False keeps paged memory layout but never shares or
    reuses: identical prompts prefill in full, and nothing parks."""
    cfg, model, params = setup()
    p = np.arange(1, 9, dtype=np.int32)
    eng = ServingEngine(model, params, slots=2, max_seq=48, paged=True,
                        page_size=4, prefix_cache=False)
    eng.submit(Request(0, p, 4))
    eng.submit(Request(1, p.copy(), 4))
    eng.run()
    st = eng.stats()["cache"]
    assert st["prefix_cache"] is False
    assert st["prefix_queries"] == 0 and st["prefix_hits"] == 0
    assert st["prefill_compute_hits"] == 0
    assert st["reused_prefill_tokens"] == 0
    assert st["blocks_cached"] == 0                  # no LRU parking


def test_cache_stats_report_concurrent_peak_across_replicas():
    """Regression: slots peaking on DIFFERENT ticks must report the
    concurrent maximum, not the sum of per-slot peaks (which would
    overstate the footprint and understate the slots gain).  Both decode
    replicas front ONE engine-global manager (rows = global slot ids) —
    the precondition for zero-copy slot migration."""
    from repro.plan import lower_serving, uniform_plan
    cfg4 = reduced(REGISTRY["yi-6b"], layers=4)
    model = build_model(cfg4)
    params = model.init(jax.random.key(0))
    plan = uniform_plan(cfg4.num_groups, 2, n_microbatches=2)
    eng = ServingEngine(model, params, slots=2, max_seq=32,
                        plan=lower_serving(plan, slots=2, chunk=4),
                        paged=True, page_size=4)
    assert len(eng._all_pagers()) == 1 and eng._pager.slots == 2
    p = np.arange(1, 9, dtype=np.int32)              # 2 blocks at page 4
    # replica 0's slot peaks (2 blocks), then fully releases ...
    eng._pager.admit(0, p, max_new_tokens=0)
    eng._pager.commit(0)
    eng._pager.release_slot(0)
    # ... and only afterwards does replica 1's slot peak (2 blocks)
    eng._pager.admit(1, np.arange(20, 28, dtype=np.int32),
                     max_new_tokens=0)
    eng._pager.commit(1)
    st = eng.cache_stats()
    # concurrent peak is 2; summing per-slot maxima would say 4
    assert st["peak_blocks_in_use"] == 2
    dense_blocks = eng.slots * (eng.max_seq // eng.page_size)
    assert st["effective_slots_gain"] == pytest.approx(dense_blocks / 2)


def test_reset_stats_clamps_wall_window_to_reset_time():
    """Regression: a request still active across reset_stats() keeps its
    pre-reset t_submit; the post-reset wall window must start at the
    reset, or throughput_tok_s is understated by the whole warmup."""
    cfg, model, params = setup()
    eng = ServingEngine(model, params, slots=1, max_seq=48)
    eng.submit(Request(0, np.array([5, 6, 7], np.int32), 8))
    eng.tick()                                    # request is now active
    eng._slot_req[0].t_submit -= 50.0             # pretend a long warmup
    eng.reset_stats()
    eng.run()
    st = eng.stats()
    # pre-fix the wall window spans the backdated 50 s: throughput would
    # be ~gen/50; post-fix the window starts at the reset
    assert st["throughput_tok_s"] > st["gen_tokens"] / 10.0


def test_prefill_token_counts_unpadded_and_speculative_stats_keys():
    """prefill_token_counts reports UNPADDED prompt tokens (bucket
    padding is a jit-shape artifact, not work), and stats() carries the
    speculative-decode keys: tokens_per_step is exactly 1.0 with
    speculation off and > 1.0 when drafts are being accepted."""
    cfg, model, params = setup()
    prompt = np.array([4, 5, 4, 5, 4, 5, 4], np.int32)   # repetitive
    off = ServingEngine(model, params, slots=2, max_seq=64)
    off.submit(Request(0, prompt.copy(), 10))
    off.run()
    assert off.prefill_token_counts == [len(prompt)]     # not the bucket
    st = off.stats()
    assert st["tokens_per_step"] == 1.0
    assert st["spec_steps"] == 0 and st["acceptance_rate"] == 0.0

    on = ServingEngine(model, params, slots=2, max_seq=64, speculate=3)
    on.submit(Request(0, prompt.copy(), 10))
    on.run()
    st = on.stats()
    assert st["spec_steps"] > 0
    assert st["spec_accepted"] > 0
    assert st["tokens_per_step"] > 1.0
    assert 0.0 < st["acceptance_rate"] <= 1.0
    assert st["decode_tokens"] == st["gen_tokens"] - 1   # first token is
    #                                                      prefill's
    # same stream with and without speculation (greedy verify)
    assert on.done[0].out_tokens == off.done[0].out_tokens


def test_speculation_gates_off_for_non_decomposable_families():
    """Families whose state cannot rewind a rejected tail (SSM mixers
    here) silently disable speculation rather than corrupt state."""
    cfg = reduced(REGISTRY["xlstm-125m"], layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    eng = ServingEngine(model, params, slots=1, max_seq=32, speculate=4)
    assert eng._spec_k == 0
    eng.submit(Request(0, np.array([1, 2, 1, 2, 1], np.int32), 5))
    eng.run()
    assert eng.stats()["spec_steps"] == 0


def test_admission_does_not_change_active_slots_next_token():
    """Admitting a request mid-stream must not perturb the token stream of
    already-active slots (no full-batch re-prefill, no position reset)."""
    cfg, model, params = setup()
    p0 = np.array([5, 6, 7], np.int32)
    p1 = np.arange(1, 9, dtype=np.int32)

    solo = ServingEngine(model, params, slots=2, max_seq=64)
    solo.submit(Request(0, p0, 8))
    solo_tokens = list(solo.run()[0].out_tokens)

    eng = ServingEngine(model, params, slots=2, max_seq=64)
    eng.submit(Request(0, p0, 8))
    for _ in range(3):
        eng.tick()
    before = list(eng._slot_req[0].out_tokens)
    eng.submit(Request(1, p1, 6))
    eng.tick()                       # tick that performs the admission
    after = list(eng._slot_req[0].out_tokens)
    assert after[:len(before)] == before
    assert after[len(before)] == solo_tokens[len(before)]  # next token kept
    while eng.tick():
        pass
    done = {r.uid: r.out_tokens for r in eng.done}
    assert done[0] == solo_tokens
    assert eng.prefill_batch_sizes == [1, 1]


def test_int8_kv_capacity_and_end_to_end_serving():
    """kv_dtype="int8" quantizes the paged K/V pools per row: every
    stream still completes its full budget, stats() reports the dtype and
    the >= 1.9x effective-capacity multiplier (int8 payload + f32 scale
    vs the fp row), and overlap composes with quantized pools."""
    cfg, model, params = setup()
    prompts = [np.arange(1, 7, dtype=np.int32),
               np.array([9, 8, 7, 6], np.int32)]
    fp = ServingEngine(model, params, slots=2, max_seq=48, paged=True,
                       page_size=4)
    q8 = ServingEngine(model, params, slots=2, max_seq=48, paged=True,
                       page_size=4, kv_dtype="int8", overlap=True)
    for uid, p in enumerate(prompts):
        fp.submit(Request(uid, p.copy(), 5))
        q8.submit(Request(uid, p.copy(), 5))
    dfp = {r.uid: r.out_tokens for r in fp.run()}
    dq8 = {r.uid: r.out_tokens for r in q8.run()}
    st = q8.stats()["cache"]
    assert st["kv_dtype"] == "int8"
    assert st["kv_capacity_x"] >= 1.9
    assert fp.stats()["cache"]["kv_dtype"] == "fp"
    assert fp.stats()["cache"]["kv_capacity_x"] == 1.0
    # quantization may perturb token IDENTITY (bounded-logit error; see
    # test_kernels), never the serving contract: full budgets, all retire
    for uid in dq8:
        assert len(dq8[uid]) == len(dfp[uid]) == 5


def test_int8_kv_logits_bounded_against_fp_paged_engine():
    """Engine-level numerics contract: teacher-forcing the fp engine's
    greedy stream through an int8 engine keeps every slot live to budget
    — and wherever the two streams agree it is because the fp logit
    margin exceeded the quantization error (checked at the kernel level);
    here we assert the streams agree on a clear-margin prompt."""
    cfg, model, params = setup()
    # constant prompt: the toy model's top-1 margin is widest here
    p = np.array([3, 3, 3, 3, 3, 3, 3, 3], np.int32)
    fp = ServingEngine(model, params, slots=1, max_seq=48, paged=True,
                       page_size=4)
    q8 = ServingEngine(model, params, slots=1, max_seq=48, paged=True,
                       page_size=4, kv_dtype="int8")
    fp.submit(Request(0, p.copy(), 4))
    q8.submit(Request(0, p.copy(), 4))
    out_fp = fp.run()[0].out_tokens
    out_q8 = q8.run()[0].out_tokens
    assert len(out_fp) == len(out_q8) == 4
    # first token comes from the (unquantized-activation) prefill logits
    # read before any decode-step dequant error can accumulate
    assert out_fp[0] == out_q8[0]


def test_kv_dtype_validation():
    cfg, model, params = setup()
    with pytest.raises(ValueError, match="kv_dtype"):
        ServingEngine(model, params, slots=2, max_seq=48, paged=True,
                      kv_dtype="int4")
    with pytest.raises(ValueError, match="paged=True"):
        ServingEngine(model, params, slots=2, max_seq=48, kv_dtype="int8")


def test_overlap_reduces_host_sync_share_and_keeps_stats_coherent():
    """The overlapped runtime's observable effect on the phase clock:
    host_sync still accrues (the delayed drain still reads back), every
    stat key stays present, and tick/throughput accounting is coherent
    while in-flight steps span tick boundaries."""
    cfg, model, params = setup()
    eng = ServingEngine(model, params, slots=2, max_seq=48, overlap=True)
    for uid in range(3):
        eng.submit(Request(uid, np.arange(1, 5 + uid, dtype=np.int32), 6))
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.out_tokens) == 6 for r in done)
    st = eng.stats()
    assert st["ticks"] > 0 and st["gen_tokens"] == 18
    pt = st["phase_time_s"]
    assert set(pt) == {"admission", "prefill", "decode", "replan",
                       "idle", "host_sync"}
    assert pt["host_sync"] > 0.0
    assert pt["host_sync"] <= pt["admission"] + pt["prefill"] + pt["decode"]
    for r in done:
        assert r.t_submit <= r.t_first <= r.t_done


# ---------------------------------------------------------------------------
# adaptive re-planning: stats continuity + controller
# ---------------------------------------------------------------------------

def _plan_setup(layers=4):
    cfg = reduced(REGISTRY["yi-6b"], layers=layers)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(0))


def test_stats_window_continuity_across_mid_window_swap():
    """Regression (stats-window continuity): a live plan swap mid-window
    must neither lose nor double-count anything — requests finished on
    either side of the swap all land in one window, decode token
    accounting stays exact, the swap's own wall time is charged to the
    "replan" phase bucket, and the wall window spans the swap instead of
    restarting at it."""
    from repro.plan import lower_serving, uniform_plan
    cfg, model, params = _plan_setup()
    plan = lower_serving(uniform_plan(cfg.num_groups, 2, n_microbatches=2),
                         slots=2, chunk=4)
    eng = ServingEngine(model, params, slots=2, max_seq=48,
                        paged=True, page_size=4)
    eng.submit(Request(0, np.arange(1, 5, dtype=np.int32), 8))
    for _ in range(4):
        eng.tick()                        # request 0 mid-decode
    r0 = eng._slot_req[0]
    pre_swap = list(r0.out_tokens)
    assert pre_swap and not eng.done
    eng.replan(plan)                      # mid-window, mid-request
    eng.submit(Request(1, np.arange(10, 16, dtype=np.int32), 6))
    eng.run()
    st = eng.stats()
    # no loss, no double count: every generated token is either the one
    # prefill-emitted first token or exactly one decode-tick token
    assert st["requests"] == 2
    assert st["gen_tokens"] == 8 + 6
    assert st["decode_tokens"] == st["gen_tokens"] - st["requests"]
    # request 0's stream was not restarted by the swap
    assert len(r0.out_tokens) == 8
    assert r0.out_tokens[:len(pre_swap)] == pre_swap
    assert st["replans"] == 1
    pt = st["phase_time_s"]
    assert pt["replan"] > 0.0             # the migration interval is charged
    assert pt["host_sync"] <= (pt["admission"] + pt["prefill"]
                               + pt["decode"] + pt["replan"])
    # the wall window spans the swap (t_submit of request 0 predates it)
    assert st["throughput_tok_s"] > 0.0
    # the shared pool's peak tracker survived the swap (attached once)
    assert len(eng._peak_tracker.pools) == 1
    # reset_stats covers the new counters too
    eng.reset_stats()
    st = eng.stats()
    assert st["replans"] == 0 and st["migrations"] == 0
    assert st["migration_copies"] == 0
    assert st["phase_time_s"]["replan"] == 0.0


def test_adaptive_controller_navigates_burst_then_idle():
    """End-to-end controller loop (analytic profiles, measure=False): a
    long-prompt burst drives the engine onto the pipelined plan, and the
    drained near-idle tail brings it back to the monolithic point —
    with every stream still completing."""
    from repro.plan import lower_serving, uniform_plan
    from repro.serving import AdaptiveConfig
    cfg, model, params = _plan_setup()
    plan = lower_serving(uniform_plan(cfg.num_groups, 2, n_microbatches=2),
                         slots=2, chunk=8)
    adapt = AdaptiveConfig(plans=[None, plan], measure=False,
                           interval_ticks=2, cooldown_ticks=2,
                           hysteresis=0.1, window_s=30.0, horizon_s=0.1)
    eng = ServingEngine(model, params, slots=2, max_seq=64,
                        paged=True, page_size=4, adapt=adapt)
    assert eng._ctl is not None
    # burst: 6 long prompts queue up behind 2 slots
    for uid in range(6):
        eng.submit(Request(uid, np.arange(1, 25, dtype=np.int32), 4))
    for _ in range(8):
        eng.tick()
    assert eng.plan == plan               # backlog -> pipelined point
    labels = [d[2] for d in eng._ctl.decisions]
    assert labels[0] == plan.label
    done = eng.run()                      # queue drains; idle tail
    assert len(done) == 6
    assert all(len(r.out_tokens) == 4 for r in done)
    assert eng.plan is None               # near-idle -> monolithic point
    assert eng.stats()["replans"] >= 2


def test_adaptive_config_validation():
    """Candidate ladders are validated at engine construction: slot
    mismatches and single-point ladders fail loudly."""
    from repro.plan import lower_serving, uniform_plan
    from repro.serving import AdaptiveConfig
    cfg, model, params = _plan_setup()
    wrong = lower_serving(uniform_plan(cfg.num_groups, 2, n_microbatches=2),
                          slots=4, chunk=4)
    with pytest.raises(ValueError, match="slots"):
        ServingEngine(model, params, slots=2, max_seq=48,
                      adapt=AdaptiveConfig(plans=[wrong]))
    with pytest.raises(ValueError, match="candidate design points"):
        ServingEngine(model, params, slots=2, max_seq=48,
                      adapt=AdaptiveConfig(plans=[]))
