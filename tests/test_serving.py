"""Serving engine: request completion, continuous batching, greedy decode
consistency."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduced
from repro.models import build_model
from repro.serving import Request, ServingEngine, make_serve_step


def setup():
    cfg = reduced(REGISTRY["yi-6b"], layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_engine_completes_requests():
    cfg, model, params = setup()
    eng = ServingEngine(model, params, slots=2, max_seq=48)
    for uid in range(4):
        eng.submit(Request(uid, np.arange(1, 4 + uid, dtype=np.int32), 6))
    done = eng.run()
    assert len(done) == 4
    for r in done:
        assert len(r.out_tokens) == 6
        assert r.t_done >= r.t_first >= r.t_submit


def test_greedy_decode_deterministic():
    cfg, model, params = setup()
    outs = []
    for _ in range(2):
        eng = ServingEngine(model, params, slots=1, max_seq=32)
        eng.submit(Request(0, np.array([5, 6, 7], np.int32), 5))
        done = eng.run()
        outs.append(tuple(done[0].out_tokens))
    assert outs[0] == outs[1]


def test_serve_step_greedy_matches_argmax():
    cfg, model, params = setup()
    step = jax.jit(make_serve_step(model))
    B, T = 2, 8
    toks = jnp.ones((B, T), jnp.int32)
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, 32))(
        params, {"tokens": toks})
    nxt, logits, cache = step(params, cache, jnp.ones((B, 1), jnp.int32),
                              jnp.int32(T))
    np.testing.assert_array_equal(
        np.asarray(nxt[:, 0]), np.asarray(jnp.argmax(logits[:, -1], -1)))
