"""Per-kernel sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.fused_matmul import matmul_fused
from repro.kernels.layernorm import norm_onepass
from repro.kernels.linear_scan import linear_scan


def _rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hk,sq,skv,d", [
    (1, 2, 2, 128, 128, 128),     # MHA
    (2, 4, 2, 256, 256, 128),     # GQA
    (1, 8, 1, 128, 384, 128),     # MQA, uneven kv
])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 128, 0.0), (False, 0, 0.0), (True, 0, 30.0),
])
def test_flash_attention(b, h, hk, sq, skv, d, dtype, causal, window,
                         softcap):
    r = _rng(hash((b, h, sq, skv)) % 2**31)
    q = jnp.asarray(r.standard_normal((b, h, sq, d)), dtype)
    k = jnp.asarray(r.standard_normal((b, hk, skv, d)), dtype)
    v = jnp.asarray(r.standard_normal((b, hk, skv, d)), dtype)
    qp = jnp.arange(sq) + (skv - sq)
    kp = jnp.arange(skv)
    kv = jnp.ones((skv,), jnp.int32)
    out = flash_attention_bhsd(q, k, v, qp, kp, kv, causal=causal,
                               window=window, softcap=softcap,
                               block_q=128, block_k=128, interpret=True)
    ref = R.flash_attention_ref(q, k, v, qp, kp, kv, causal=causal,
                                window=window, softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_ring_validity():
    """Decode over a ring cache: invalid (unwritten) slots must be ignored."""
    r = _rng(3)
    b, h, skv, d = 1, 2, 128, 128
    q = jnp.asarray(r.standard_normal((b, h, 8, d)), jnp.float32)
    k = jnp.asarray(r.standard_normal((b, h, skv, d)), jnp.float32)
    v = jnp.asarray(r.standard_normal((b, h, skv, d)), jnp.float32)
    k_pos = jnp.where(jnp.arange(skv) < 40, jnp.arange(skv), -1)
    k_valid = (k_pos >= 0).astype(jnp.int32)
    qp = jnp.arange(8) + 40
    out = flash_attention_bhsd(q, k, v, qp, k_pos, k_valid, causal=True,
                               interpret=True)
    ref = R.flash_attention_ref(q, k, v, qp, k_pos, k_valid, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# fused matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (256, 512, 384),
                                   (512, 128, 256)])
@pytest.mark.parametrize("act", ["none", "gelu", "silu", "relu2"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_fused(m, k, n, act, dtype):
    r = _rng(hash((m, k, n)) % 2**31)
    x = jnp.asarray(r.standard_normal((m, k)) * 0.1, dtype)
    w = jnp.asarray(r.standard_normal((k, n)) * 0.1, dtype)
    b = jnp.asarray(r.standard_normal((n,)) * 0.1, dtype)
    out = matmul_fused(x, w, b, activation=act, block_m=128, block_n=128,
                       block_k=128, interpret=True)
    ref = R.matmul_fused_ref(x, w, b, activation=act)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_matmul_fused_no_bias():
    r = _rng(9)
    x = jnp.asarray(r.standard_normal((128, 128)), jnp.float32)
    w = jnp.asarray(r.standard_normal((128, 128)), jnp.float32)
    out = matmul_fused(x, w, None, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(x @ w), atol=1e-3, rtol=1e-4)


# ---------------------------------------------------------------------------
# one-pass norm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["rmsnorm", "layernorm"])
@pytest.mark.parametrize("r_,d", [(128, 256), (512, 384), (256, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_norm_onepass(kind, r_, d, dtype):
    r = _rng(hash((kind, r_, d)) % 2**31)
    x = jnp.asarray(r.standard_normal((r_, d)), dtype)
    s = jnp.asarray(r.standard_normal((d,)), dtype)
    b = jnp.asarray(r.standard_normal((d,)), dtype)
    out = norm_onepass(x, s, b, kind=kind, block_rows=128, interpret=True)
    ref = R.norm_onepass_ref(x, s, b, kind=kind)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# linear scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,s,f", [(2, 128, 256), (4, 256, 128),
                                   (1, 512, 512)])
def test_linear_scan(n, s, f):
    r = _rng(hash((n, s, f)) % 2**31)
    a = jnp.asarray(r.uniform(0.5, 0.999, (n, s, f)), jnp.float32)
    b = jnp.asarray(r.standard_normal((n, s, f)), jnp.float32)
    h0 = jnp.asarray(r.standard_normal((n, f)), jnp.float32)
    out = linear_scan(a, b, h0, block_s=128, block_f=128, interpret=True)
    ref = R.linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_linear_scan_matches_chunked_model_path():
    """The model-side chunked associative scan must agree with the kernel."""
    from repro.models.ssm import linear_scan_chunked
    r = _rng(11)
    a = jnp.asarray(r.uniform(0.5, 0.999, (2, 128, 8, 4)), jnp.float32)
    b = jnp.asarray(r.standard_normal((2, 128, 8, 4)), jnp.float32)
    h_all, h_last = linear_scan_chunked(a, b, chunk=32)
    flat = linear_scan(a.reshape(2, 128, 32), b.reshape(2, 128, 32),
                       block_s=64, block_f=32, interpret=True)
    np.testing.assert_allclose(np.asarray(h_all.reshape(2, 128, 32)),
                               np.asarray(flat), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last),
                               np.asarray(h_all[:, -1]), atol=1e-6)
