"""Per-kernel sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.fused_matmul import matmul_fused
from repro.kernels.layernorm import norm_onepass
from repro.kernels.linear_scan import linear_scan


def _rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hk,sq,skv,d", [
    (1, 2, 2, 128, 128, 128),     # MHA
    (2, 4, 2, 256, 256, 128),     # GQA
    (1, 8, 1, 128, 384, 128),     # MQA, uneven kv
])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 128, 0.0), (False, 0, 0.0), (True, 0, 30.0),
])
def test_flash_attention(b, h, hk, sq, skv, d, dtype, causal, window,
                         softcap):
    r = _rng(hash((b, h, sq, skv)) % 2**31)
    q = jnp.asarray(r.standard_normal((b, h, sq, d)), dtype)
    k = jnp.asarray(r.standard_normal((b, hk, skv, d)), dtype)
    v = jnp.asarray(r.standard_normal((b, hk, skv, d)), dtype)
    qp = jnp.arange(sq) + (skv - sq)
    kp = jnp.arange(skv)
    kv = jnp.ones((skv,), jnp.int32)
    out = flash_attention_bhsd(q, k, v, qp, kp, kv, causal=causal,
                               window=window, softcap=softcap,
                               block_q=128, block_k=128, interpret=True)
    ref = R.flash_attention_ref(q, k, v, qp, kp, kv, causal=causal,
                                window=window, softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_ring_validity():
    """Decode over a ring cache: invalid (unwritten) slots must be ignored."""
    r = _rng(3)
    b, h, skv, d = 1, 2, 128, 128
    q = jnp.asarray(r.standard_normal((b, h, 8, d)), jnp.float32)
    k = jnp.asarray(r.standard_normal((b, h, skv, d)), jnp.float32)
    v = jnp.asarray(r.standard_normal((b, h, skv, d)), jnp.float32)
    k_pos = jnp.where(jnp.arange(skv) < 40, jnp.arange(skv), -1)
    k_valid = (k_pos >= 0).astype(jnp.int32)
    qp = jnp.arange(8) + 40
    out = flash_attention_bhsd(q, k, v, qp, k_pos, k_valid, causal=True,
                               interpret=True)
    ref = R.flash_attention_ref(q, k, v, qp, k_pos, k_valid, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# fused matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (256, 512, 384),
                                   (512, 128, 256)])
@pytest.mark.parametrize("act", ["none", "gelu", "silu", "relu2"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_fused(m, k, n, act, dtype):
    r = _rng(hash((m, k, n)) % 2**31)
    x = jnp.asarray(r.standard_normal((m, k)) * 0.1, dtype)
    w = jnp.asarray(r.standard_normal((k, n)) * 0.1, dtype)
    b = jnp.asarray(r.standard_normal((n,)) * 0.1, dtype)
    out = matmul_fused(x, w, b, activation=act, block_m=128, block_n=128,
                       block_k=128, interpret=True)
    ref = R.matmul_fused_ref(x, w, b, activation=act)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_matmul_fused_no_bias():
    r = _rng(9)
    x = jnp.asarray(r.standard_normal((128, 128)), jnp.float32)
    w = jnp.asarray(r.standard_normal((128, 128)), jnp.float32)
    out = matmul_fused(x, w, None, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(x @ w), atol=1e-3, rtol=1e-4)


# ---------------------------------------------------------------------------
# one-pass norm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["rmsnorm", "layernorm"])
@pytest.mark.parametrize("r_,d", [(128, 256), (512, 384), (256, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_norm_onepass(kind, r_, d, dtype):
    r = _rng(hash((kind, r_, d)) % 2**31)
    x = jnp.asarray(r.standard_normal((r_, d)), dtype)
    s = jnp.asarray(r.standard_normal((d,)), dtype)
    b = jnp.asarray(r.standard_normal((d,)), dtype)
    out = norm_onepass(x, s, b, kind=kind, block_rows=128, interpret=True)
    ref = R.norm_onepass_ref(x, s, b, kind=kind)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# linear scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,s,f", [(2, 128, 256), (4, 256, 128),
                                   (1, 512, 512)])
def test_linear_scan(n, s, f):
    r = _rng(hash((n, s, f)) % 2**31)
    a = jnp.asarray(r.uniform(0.5, 0.999, (n, s, f)), jnp.float32)
    b = jnp.asarray(r.standard_normal((n, s, f)), jnp.float32)
    h0 = jnp.asarray(r.standard_normal((n, f)), jnp.float32)
    out = linear_scan(a, b, h0, block_s=128, block_f=128, interpret=True)
    ref = R.linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_linear_scan_matches_chunked_model_path():
    """The model-side chunked associative scan must agree with the kernel."""
    from repro.models.ssm import linear_scan_chunked
    r = _rng(11)
    a = jnp.asarray(r.uniform(0.5, 0.999, (2, 128, 8, 4)), jnp.float32)
    b = jnp.asarray(r.standard_normal((2, 128, 8, 4)), jnp.float32)
    h_all, h_last = linear_scan_chunked(a, b, chunk=32)
    flat = linear_scan(a.reshape(2, 128, 32), b.reshape(2, 128, 32),
                       block_s=64, block_f=32, interpret=True)
    np.testing.assert_allclose(np.asarray(h_all.reshape(2, 128, 32)),
                               np.asarray(flat), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last),
                               np.asarray(h_all[:, -1]), atol=1e-6)


# ---------------------------------------------------------------------------
# fused paged decode (RoPE + page write + attention in one kernel)
# ---------------------------------------------------------------------------

def _fused_setup(seed, *, b=3, h=4, hk=2, d=128, page=32, nb=3,
                 kv_dtype=jnp.float32, int8=False):
    """Random fused-decode operands with DISJOINT per-slot page tables
    (+ a trailing sink page).  Disjointness mirrors the engine's
    ownership invariant — write pages are exclusively owned — which the
    in-place aliased kernel writes rely on; colliding random tables
    would interleave one slot's write with another's gather."""
    r = _rng(seed)
    n = b * nb + 1                                   # + sink page
    q = jnp.asarray(r.standard_normal((b, hk, h // hk, d)), jnp.float32)
    kn = jnp.asarray(r.standard_normal((b, hk, d)), jnp.float32)
    vn = jnp.asarray(r.standard_normal((b, hk, d)), jnp.float32)
    bt = jnp.asarray(r.permutation(b * nb).reshape(b, nb), jnp.int32)
    pos = jnp.asarray([page - 1, page + 5, 2 * page + 17][:b], jnp.int32)
    if int8:
        kf = r.standard_normal((n, page, hk, d))
        vf = r.standard_normal((n, page, hk, d))
        kp, ks = R.quantize_int8_rows(jnp.asarray(kf, jnp.float32))
        vp, vs = R.quantize_int8_rows(jnp.asarray(vf, jnp.float32))
        return q, kn, vn, kp, vp, bt, pos, ks, vs
    kp = jnp.asarray(r.standard_normal((n, page, hk, d)), kv_dtype)
    vp = jnp.asarray(r.standard_normal((n, page, hk, d)), kv_dtype)
    return q, kn, vn, kp, vp, bt, pos, None, None


def test_decode_rope_ref_matches_model_apply_rope_bitwise():
    """The kernel-side RoPE reference must be BITWISE the model's
    ``apply_rope`` (no-mrope branch) — the fused path's fp parity
    guarantee hangs on this."""
    from repro.models.layers import apply_rope
    r = _rng(21)
    x = jnp.asarray(r.standard_normal((2, 1, 4, 64)), jnp.float32)
    pos = jnp.asarray([[7], [123]], jnp.int32)
    a = R.decode_rope_ref(x, pos, 10000.0)
    b = apply_rope(x, pos, 10000.0)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_fused_paged_decode_ref_composes_unfused_ops_bitwise():
    """fused_paged_decode_ref == rope -> scatter -> paged_attention_ref,
    bit-for-bit (it IS that composition — this pins it)."""
    q, kn, vn, kp, vp, bt, pos, _, _ = _fused_setup(31)
    theta, page = 10000.0, kp.shape[1]
    out, nkp, nvp, _, _ = R.fused_paged_decode_ref(
        q, kn, vn, kp, vp, bt, pos, theta=theta)
    b, hk, g, d = q.shape
    qr = R.decode_rope_ref(q.reshape(b, 1, hk * g, d), pos[:, None],
                           theta).reshape(b, hk, g, d)
    kr = R.decode_rope_ref(kn[:, None], pos[:, None], theta)[:, 0]
    blk = jnp.clip(pos // page, 0, bt.shape[1] - 1)
    pages = jnp.take_along_axis(bt, blk[:, None], axis=1)[:, 0]
    rows = pos % page
    ekp = kp.at[pages, rows].set(kr, mode="drop")
    evp = vp.at[pages, rows].set(vn, mode="drop")
    eout = R.paged_attention_ref(qr, ekp, evp, bt, pos + 1)
    assert np.array_equal(np.asarray(nkp), np.asarray(ekp))
    assert np.array_equal(np.asarray(nvp), np.asarray(evp))
    assert np.array_equal(np.asarray(out), np.asarray(eout))


@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_fused_paged_decode_kernel_matches_ref(softcap):
    from repro.kernels.paged_attention import fused_paged_decode_grouped
    q, kn, vn, kp, vp, bt, pos, _, _ = _fused_setup(32)
    kw = dict(theta=10000.0, softcap=softcap)
    ro, rkp, rvp, _, _ = R.fused_paged_decode_ref(q, kn, vn, kp, vp, bt,
                                                  pos, **kw)
    io_, ikp, ivp, _, _ = fused_paged_decode_grouped(
        q, kn, vn, kp, vp, bt, pos, interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(io_), np.asarray(ro),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(ikp), np.asarray(rkp),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(ivp), np.asarray(rvp),
                               atol=2e-5, rtol=2e-5)


def test_fused_paged_decode_kernel_int8_matches_ref():
    """int8 pools: the kernel's in-kernel quantization must produce the
    same int8 rows and scales as the reference (both quantize the same
    roped fp row with the same symmetric rule), and the attention output
    must match on the dequantized values."""
    from repro.kernels.paged_attention import fused_paged_decode_grouped
    q, kn, vn, kp, vp, bt, pos, ks, vs = _fused_setup(33, int8=True)
    kw = dict(theta=10000.0, k_scales=ks, v_scales=vs)
    ro, rkp, rvp, rks, rvs = R.fused_paged_decode_ref(q, kn, vn, kp, vp,
                                                      bt, pos, **kw)
    io_, ikp, ivp, iks, ivs = fused_paged_decode_grouped(
        q, kn, vn, kp, vp, bt, pos, interpret=True, **kw)
    assert ikp.dtype == jnp.int8 and iks.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(ikp), np.asarray(rkp))
    np.testing.assert_array_equal(np.asarray(ivp), np.asarray(rvp))
    np.testing.assert_allclose(np.asarray(iks), np.asarray(rks),
                               atol=1e-7, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ivs), np.asarray(rvs),
                               atol=1e-7, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(io_), np.asarray(ro),
                               atol=2e-5, rtol=2e-5)


def test_fused_paged_decode_int8_tracks_fp_within_quant_noise():
    """int8 dequant attention stays within a small bounded error of the
    fp path on the same values (per-row symmetric int8: |err| <=
    scale/2 per element, softmax-averaged)."""
    q, kn, vn, kp, vp, bt, pos, _, _ = _fused_setup(34)
    ks_q, ks = R.quantize_int8_rows(kp)
    vs_q, vs = R.quantize_int8_rows(vp)
    fp, *_ = R.fused_paged_decode_ref(q, kn, vn, kp, vp, bt, pos,
                                      theta=10000.0)
    q8, *_ = R.fused_paged_decode_ref(q, kn, vn, ks_q, vs_q, bt, pos,
                                      theta=10000.0, k_scales=ks,
                                      v_scales=vs)
    err = np.abs(np.asarray(fp) - np.asarray(q8)).max()
    assert err < 0.1, err                 # N(0,1) values, scale ~ 4/127


def test_int8_quantize_roundtrip_error_bound():
    r = _rng(35)
    x = jnp.asarray(r.standard_normal((5, 7, 128)), jnp.float32)
    q, s = R.quantize_int8_rows(x)
    assert q.dtype == jnp.int8 and s.shape == x.shape[:-1]
    back = R.dequantize_int8(q, s)
    # symmetric rounding: elementwise error <= scale/2
    bound = np.asarray(s)[..., None] * 0.5 + 1e-7
    assert (np.abs(np.asarray(back) - np.asarray(x)) <= bound).all()
    # zero rows must not divide by zero
    q0, s0 = R.quantize_int8_rows(jnp.zeros((2, 3, 8)))
    assert np.asarray(q0).max() == 0 and np.isfinite(np.asarray(s0)).all()
