"""The version-portability layer: the shim must pick working symbols on the
installed jax, the dispatch front door must fall back to interpret/ref
off-TPU with ref-parity, and no jax version probe / pltpu construction may
exist outside ``repro.backend``."""
import os
import re

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.backend import compat, dispatch
from repro.kernels import ref as R

REPO = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# shim sanity on the installed jax
# ---------------------------------------------------------------------------

def test_version_parses_into_supported_range():
    v = compat.jax_version()
    assert len(v) >= 2 and v >= (0, 4), v


def test_tpu_compiler_params_constructs():
    p = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    assert tuple(p.dimension_semantics) == ("parallel", "arbitrary")


def test_vmem_scratch_constructs():
    s = compat.vmem_scratch((8, 128), jnp.float32)
    assert s is not None


def test_make_mesh_and_use_mesh_context():
    mesh = compat.make_mesh((len(jax.devices()), 1), ("data", "model"))
    assert mesh.shape["model"] == 1
    with compat.use_mesh(mesh):
        y = jax.jit(lambda x: x * 2)(jnp.ones((4,)))
    np.testing.assert_allclose(np.asarray(y), 2.0)


def test_make_abstract_mesh_both_signatures():
    m = compat.make_abstract_mesh((4, 2), ("data", "model"))
    assert m.shape["data"] == 4 and m.shape["model"] == 2
    assert tuple(m.axis_names) == ("data", "model")
    assert compat.mesh_axis_size(m, ("data", "model")) == 8
    assert compat.mesh_axis_size(m, "absent") == 1


def test_shard_map_single_device_roundtrip():
    from jax.sharding import PartitionSpec as P
    mesh = compat.make_mesh((len(jax.devices()),), ("data",))
    f = compat.shard_map(lambda x: x + 1, mesh=mesh, in_specs=P("data"),
                         out_specs=P("data"),
                         manual_axes=frozenset({"data"}))
    y = f(jnp.zeros((len(jax.devices()) * 2,)))
    np.testing.assert_allclose(np.asarray(y), 1.0)


def test_pcast_varying_is_safe_identity_semantics():
    # Only the no-op branch is exercisable outside shard_map; on jax with a
    # real pcast/pvary the executor tests cover the varying-cast in context.
    x = jnp.ones((2, 2))
    if not hasattr(jax.lax, "pcast") and not hasattr(jax.lax, "pvary"):
        np.testing.assert_allclose(np.asarray(compat.pcast_varying(x, ("s",))),
                                   np.asarray(x))


# ---------------------------------------------------------------------------
# dispatch front door: off-TPU fallback + ref parity
# ---------------------------------------------------------------------------

@pytest.fixture
def force_path(monkeypatch):
    def _force(path):
        monkeypatch.setenv("REPRO_KERNELS", path)
        jax.clear_caches()
    yield _force
    jax.clear_caches()


def test_kernel_path_defaults_off_tpu(monkeypatch):
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    expected = "pallas" if compat.on_tpu() else "ref"
    assert dispatch.kernel_path() == expected


def test_kernel_path_env_override(monkeypatch):
    for path in ("ref", "interpret", "pallas"):
        monkeypatch.setenv("REPRO_KERNELS", path)
        assert dispatch.kernel_path() == path


def test_kernel_path_rejects_unknown_value_listing_choices(monkeypatch):
    """A typo'd REPRO_KERNELS must fail loudly (silently falling back to
    the jnp oracle would fake a kernel benchmark), naming the choices —
    paths AND the per-kernel override names."""
    monkeypatch.setenv("REPRO_KERNELS", "garbage")
    with pytest.raises(ValueError, match=r"garbage.*auto.*pallas.*"
                                         r"interpret.*ref.*"
                                         r"fused_paged_decode"):
        dispatch.kernel_path()


def test_kernel_path_per_kernel_override(monkeypatch):
    """REPRO_KERNELS is a comma-separated list: one base path plus
    per-kernel 'name=path' overrides routed by kernel name."""
    monkeypatch.setenv("REPRO_KERNELS",
                       "ref,fused_paged_decode=interpret,matmul=pallas")
    assert dispatch.kernel_path() == "ref"
    assert dispatch.kernel_path("paged_attention") == "ref"
    assert dispatch.kernel_path("fused_paged_decode") == "interpret"
    assert dispatch.kernel_path("matmul") == "pallas"
    # override-only form: base stays auto
    monkeypatch.setenv("REPRO_KERNELS", "fused_paged_decode=ref")
    assert dispatch.kernel_path("fused_paged_decode") == "ref"


def test_kernel_path_rejects_bad_override(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "ref,not_a_kernel=ref")
    with pytest.raises(ValueError, match=r"not_a_kernel.*fused_paged"):
        dispatch.kernel_path()
    monkeypatch.setenv("REPRO_KERNELS", "ref,matmul=fast")
    with pytest.raises(ValueError, match=r"matmul=fast"):
        dispatch.kernel_path()


@pytest.mark.parametrize("path", ["ref", "interpret"])
def test_dispatch_matmul_ref_parity(path, force_path):
    force_path(path)
    r = np.random.default_rng(0)
    x = jnp.asarray(r.standard_normal((128, 128)) * 0.1, jnp.float32)
    w = jnp.asarray(r.standard_normal((128, 128)) * 0.1, jnp.float32)
    b = jnp.asarray(r.standard_normal((128,)) * 0.1, jnp.float32)
    out = dispatch.dispatch_matmul(x, w, b, activation="gelu")
    ref = R.matmul_fused_ref(x, w, b, activation="gelu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("path", ["ref", "interpret"])
def test_dispatch_flash_attention_ref_parity(path, force_path):
    force_path(path)
    r = np.random.default_rng(1)
    b, h, s, d = 1, 2, 128, 128
    # model layout (B, S, H, D)
    q = jnp.asarray(r.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(r.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(r.standard_normal((b, s, h, d)), jnp.float32)
    pos = jnp.arange(s)
    out = dispatch.dispatch_flash_attention(q, k, v, q_pos=pos, k_pos=pos,
                                            causal=True)
    ref = R.flash_attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        pos, pos, jnp.ones((s,), jnp.int32), causal=True)
    ref = jnp.swapaxes(ref, 1, 2).reshape(b, s, h * d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("path", ["ref", "interpret"])
def test_dispatch_paged_attention_ref_parity(path, force_path):
    """The paged decode kernel (block-table gather via scalar prefetch)
    matches the jnp oracle on CPU — including unmapped table entries and
    short lengths."""
    force_path(path)
    r = np.random.default_rng(4)
    b, h, hk, d = 3, 4, 2, 128
    n, page, nb = 8, 16, 4
    q = jnp.asarray(r.standard_normal((b, 1, h, d)), jnp.float32)
    kp = jnp.asarray(r.standard_normal((n, page, hk, d)), jnp.float32)
    vp = jnp.asarray(r.standard_normal((n, page, hk, d)), jnp.float32)
    bt = jnp.asarray([[3, 1, n, n], [5, 2, 7, n], [0, n, n, n]], jnp.int32)
    lens = jnp.asarray([20, 37, 3], jnp.int32)
    out = dispatch.dispatch_paged_attention(q, kp, vp, bt, lens)
    qg = q[:, 0].reshape(b, hk, h // hk, d)
    ref = R.paged_attention_ref(qg, kp, vp, jnp.clip(bt, 0, n - 1),
                                lens).reshape(b, 1, h * d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("path", ["ref", "interpret"])
@pytest.mark.parametrize("offset", [0, 10])
def test_dispatch_paged_prefill_attention_ref_parity(path, offset,
                                                    force_path):
    """The paged prefill kernel (suffix queries attending the full mapped
    prefix through the block table) matches the jnp oracle — cold
    (offset=0) and warm (offset>0), with a sentinel pad entry whose rows
    the causal mask must exclude."""
    force_path(path)
    r = np.random.default_rng(5)
    b, h, hk, d = 1, 4, 2, 128
    n, page = 8, 8
    s = 8
    q = jnp.asarray(r.standard_normal((b, s, h, d)), jnp.float32)
    kp = jnp.asarray(r.standard_normal((n, page, hk, d)), jnp.float32)
    vp = jnp.asarray(r.standard_normal((n, page, hk, d)), jnp.float32)
    bt = jnp.asarray([[3, 1, 6, n]], jnp.int32)  # covers offset+s, 1 pad
    out = dispatch.dispatch_paged_prefill_attention(q, kp, vp, bt, offset)
    qg = jnp.swapaxes(q, 1, 2).reshape(b, hk, h // hk, s, d)
    ref = R.paged_prefill_attention_ref(qg, kp, vp, bt, offset)
    ref = jnp.swapaxes(ref.reshape(b, h, s, d), 1, 2).reshape(b, s, h * d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("path", ["ref", "interpret"])
def test_dispatch_linear_scan_ref_parity(path, force_path):
    force_path(path)
    r = np.random.default_rng(2)
    a = jnp.asarray(r.uniform(0.5, 0.999, (2, 128, 128)), jnp.float32)
    b = jnp.asarray(r.standard_normal((2, 128, 128)), jnp.float32)
    out = dispatch.dispatch_linear_scan(a, b)
    ref = R.linear_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("path", ["ref", "interpret"])
def test_dispatch_layernorm_ref_parity(path, force_path):
    force_path(path)
    r = np.random.default_rng(3)
    x = jnp.asarray(r.standard_normal((128, 256)), jnp.float32)
    s = jnp.asarray(r.standard_normal((256,)), jnp.float32)
    b = jnp.asarray(r.standard_normal((256,)), jnp.float32)
    for kind in ("rmsnorm", "layernorm"):
        out = dispatch.dispatch_layernorm(x, s, b, kind=kind)
        ref = R.norm_onepass_ref(x, s, b, kind=kind)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


def test_use_scan_kernel_follows_path(force_path):
    force_path("ref")
    assert not dispatch.use_scan_kernel()
    force_path("interpret")
    assert dispatch.use_scan_kernel()


# ---------------------------------------------------------------------------
# source guard: version probes / pltpu stay inside the compat layer
# ---------------------------------------------------------------------------

# constructed so this file doesn't match its own patterns
_FORBIDDEN = [
    ("pltpu" + ".", "pallas-TPU symbol construction"),
    ("Axis" + "Type", "jax.sharding.AxisType probe"),
    ("hasattr(jax" + ",", "jax API version probe"),
    ("hasattr(jax" + ".", "jax API version probe"),
    ("jax" + ".__version__", "jax version read"),
    ("default_" + "backend", "backend probe"),
    ("pallas import" + " tpu", "pltpu import"),
]
_ALLOWED = {os.path.join("src", "repro", "backend", "compat.py")}


def _py_sources():
    for root in ("src", "tests", "benchmarks", "examples"):
        base = os.path.join(REPO, root)
        for dirpath, _, files in os.walk(base):
            for fn in files:
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def test_no_version_probes_outside_compat():
    this_file = os.path.abspath(__file__)
    offenders = []
    for path in _py_sources():
        rel = os.path.relpath(path, REPO)
        if rel in _ALLOWED or os.path.abspath(path) == this_file:
            continue
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for i, line in enumerate(text.splitlines(), 1):
            for pat, why in _FORBIDDEN:
                if pat in line:
                    offenders.append(f"{rel}:{i}: {why}: {line.strip()}")
    assert not offenders, "\n".join(offenders)


@pytest.mark.parametrize("path", ["ref", "interpret"])
def test_dispatch_fused_paged_decode_ref_parity(path, force_path):
    """The fused RoPE+write+attend decode kernel matches the jnp oracle
    through the dispatch front door — output AND the written pools.
    Tables are disjoint per slot (the engine's ownership invariant the
    in-place kernel writes rely on)."""
    force_path(path)
    r = np.random.default_rng(6)
    b, h, hk, d = 2, 4, 2, 128
    page, nb = 32, 2
    n = b * nb + 1                                   # + sink page
    q = jnp.asarray(r.standard_normal((b, 1, h, d)), jnp.float32)
    kn = jnp.asarray(r.standard_normal((b, 1, hk, d)), jnp.float32)
    vn = jnp.asarray(r.standard_normal((b, 1, hk, d)), jnp.float32)
    kp = jnp.asarray(r.standard_normal((n, page, hk, d)), jnp.float32)
    vp = jnp.asarray(r.standard_normal((n, page, hk, d)), jnp.float32)
    bt = jnp.asarray(r.permutation(b * nb).reshape(b, nb), jnp.int32)
    pos = jnp.asarray([page - 1, page + 7], jnp.int32)
    out, nkp, nvp, ks, vs = dispatch.dispatch_fused_paged_decode(
        q, kn, vn, kp, vp, bt, pos, theta=10000.0)
    assert ks is None and vs is None
    qg = q[:, 0].reshape(b, hk, h // hk, d)
    ro, rkp, rvp, _, _ = R.fused_paged_decode_ref(
        qg, kn[:, 0], vn[:, 0], kp, vp, bt, pos, theta=10000.0)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ro.reshape(b, 1, h * d)),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(nkp), np.asarray(rkp),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(nvp), np.asarray(rvp),
                               atol=2e-5, rtol=2e-5)


def test_dispatch_paged_attention_int8_dequant_parity(force_path):
    """int8 pools route through the dequantizing oracle on every path
    and match an fp pool holding the dequantized values exactly."""
    force_path("interpret")     # int8 still forces the ref fallback here
    r = np.random.default_rng(7)
    b, h, hk, d = 2, 4, 2, 128
    n, page, nb = 5, 16, 2
    q = jnp.asarray(r.standard_normal((b, 1, h, d)), jnp.float32)
    kf = jnp.asarray(r.standard_normal((n, page, hk, d)), jnp.float32)
    vf = jnp.asarray(r.standard_normal((n, page, hk, d)), jnp.float32)
    kq, ks = R.quantize_int8_rows(kf)
    vq, vs = R.quantize_int8_rows(vf)
    bt = jnp.asarray([[3, 1], [0, 2]], jnp.int32)
    lens = jnp.asarray([20, 9], jnp.int32)
    out = dispatch.dispatch_paged_attention(q, kq, vq, bt, lens,
                                            k_scales=ks, v_scales=vs)
    deq = dispatch.dispatch_paged_attention(
        q, R.dequantize_int8(kq, ks), R.dequantize_int8(vq, vs), bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(deq),
                               atol=2e-6, rtol=2e-6)
