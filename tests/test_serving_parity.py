"""Decode-parity harness for per-slot continuous batching.

Gold standard: each request decoded in ISOLATION (batch-1 prefill + one
greedy decode loop).  The engine must reproduce every request's token
stream exactly — same tokens, any arrival order, any prompt-length mix,
any slot count — and admission must prefill only the admitted slot
(batch-1 prefills, one per request; never a full-batch re-prefill).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduced
from repro.models import build_model
from repro.serving import Request, ServingEngine, make_serve_step


def build(arch="yi-6b", layers=1, key=0):
    cfg = reduced(REGISTRY[arch], layers=layers)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(key))


def gold_decode(model, params, prompt, max_new, max_seq, eos=None):
    """Isolated one-shot greedy decode: the parity reference."""
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, max_seq))(
        params, {"tokens": jnp.asarray(prompt[None])})
    out = [int(jnp.argmax(logits[0, -1]))]
    step = jax.jit(make_serve_step(model))
    pos = len(prompt)
    while len(out) < max_new and pos < max_seq - 1 \
            and (eos is None or out[-1] != eos):
        nxt, _, cache = step(params, cache,
                             jnp.asarray([[out[-1]]], jnp.int32),
                             jnp.int32(pos))
        out.append(int(nxt[0, 0]))
        pos += 1
    return out


STAGGERED = [  # (prompt, max_new, submit_after_tick)
    (np.arange(1, 4, dtype=np.int32), 6, 0),
    (np.arange(5, 14, dtype=np.int32), 8, 0),     # longer prompt, same wave
    (np.array([9, 8, 7, 6, 5], np.int32), 5, 2),  # arrives mid-decode
    (np.array([2, 2], np.int32), 7, 4),           # late, shortest prompt
]


def run_staggered(model, params, slots, max_seq=64, plan=STAGGERED):
    eng = ServingEngine(model, params, slots=slots, max_seq=max_seq)
    pending = sorted(enumerate(plan), key=lambda x: x[1][2])
    tick = 0
    busy = True
    while busy or pending:
        while pending and pending[0][1][2] <= tick:
            uid, (prompt, max_new, _) = pending.pop(0)
            eng.submit(Request(uid, prompt, max_new))
        busy = eng.tick()
        tick += 1
    return eng


@pytest.mark.parametrize("slots", [1, 2, 3])
def test_staggered_arrivals_match_one_shot_decode(slots):
    """The tentpole guarantee: continuous batching is token-identical to
    per-request isolated greedy decode, at every slot count."""
    cfg, model, params = build()
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    eng = run_staggered(model, params, slots)
    got = {r.uid: r.out_tokens for r in eng.done}
    assert len(got) == len(STAGGERED)
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"slots={slots} uid={uid}"


def test_admission_prefills_only_the_admitted_slot():
    """No full-batch re-prefill: one batch-1 prefill per request, O(prompt)
    tokens each, even when other slots are mid-decode at admission."""
    cfg, model, params = build()
    eng = run_staggered(model, params, slots=2)
    assert eng.prefill_batch_sizes == [1] * len(STAGGERED)
    bucket = eng.prefill_bucket
    for toks, (prompt, _, _) in zip(
            eng.prefill_token_counts,
            sorted(STAGGERED, key=lambda x: x[2])):
        assert toks <= -(-len(prompt) // bucket) * bucket


def test_slot_cache_survives_retirement_and_readmission():
    """A freed slot's next occupant decodes correctly (the admission
    scatter fully replaces the slot's cache rows and recurrent state)."""
    cfg, model, params = build()
    prompts = [np.arange(1, 4 + i, dtype=np.int32) for i in range(5)]
    golds = [gold_decode(model, params, p, 4, 48) for p in prompts]
    eng = ServingEngine(model, params, slots=2, max_seq=48)
    for uid, p in enumerate(prompts):           # 5 requests through 2 slots
        eng.submit(Request(uid, p, 4))
    done = {r.uid: r.out_tokens for r in eng.run()}
    for uid, gold in enumerate(golds):
        assert done[uid] == gold


def test_eos_retires_one_slot_without_stalling_others():
    """Slot-level EOS retirement: the EOS'd request stops at its EOS token
    while the other slot's stream is unaffected (still gold-identical)."""
    cfg, model, params = build()
    p0 = np.arange(1, 4, dtype=np.int32)
    p1 = np.arange(5, 10, dtype=np.int32)
    g0 = gold_decode(model, params, p0, 8, 64)
    eos = g0[2]                                  # force EOS three tokens in
    g0_eos = gold_decode(model, params, p0, 8, 64, eos=eos)
    g1 = gold_decode(model, params, p1, 8, 64)
    eng = ServingEngine(model, params, slots=2, max_seq=64)
    eng.submit(Request(0, p0, 8, eos_token=eos))
    eng.submit(Request(1, p1, 8))
    done = {r.uid: r.out_tokens for r in eng.run()}
    assert done[0] == g0_eos and done[0][-1] == eos and len(done[0]) == 3
    assert done[1] == g1


def test_parity_with_prompts_longer_than_local_window():
    """Sliding-window regression: a padded prompt must never spill past
    the attn_local ring (pad k/v would evict real in-window tokens), and
    a prompt longer than the window itself must wrap the ring exactly as
    the gold one-shot prefill does."""
    cfg, model, params = build("gemma2-9b", layers=2, key=1)
    window = min(64, cfg.window_size)
    eng0 = ServingEngine(model, params, slots=2, max_seq=64)
    assert eng0.prefill_bucket > 1          # the bucketed path is under test
    prompts = [
        np.arange(1, 2 + window, dtype=np.int32),       # window + 1 tokens
        np.arange(2, 6 + window, dtype=np.int32),       # window + 4 tokens
        np.arange(3, window, dtype=np.int32),           # pads up to window
    ]
    golds = [gold_decode(model, params, p, 5, 64) for p in prompts]
    eng = ServingEngine(model, params, slots=2, max_seq=64)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, 5))
    done = {r.uid: r.out_tokens for r in eng.run()}
    for uid, gold in enumerate(golds):
        assert done[uid] == gold, f"uid={uid}"
    for toks, p in zip(eng.prefill_token_counts, prompts):
        assert len(p) <= toks <= max(len(p), window)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b", "xlstm-125m",
                                  "qwen2-moe-a2.7b", "gemma2-9b"])
def test_parity_across_families(arch):
    """Hybrid (attn+mamba+moe), pure-SSM, MoE, and local-window attention
    families all hold the parity guarantee (recurrent state and expert
    routing ride the same slot-scatter admission path)."""
    cfg, model, params = build(
        arch, layers=len(REGISTRY[arch].block_pattern), key=1)
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    eng = run_staggered(model, params, slots=2)
    got = {r.uid: r.out_tokens for r in eng.done}
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"{arch} uid={uid}"
