"""Decode-parity harness for per-slot continuous batching.

Gold standard: each request decoded in ISOLATION (batch-1 prefill + one
greedy decode loop).  The engine must reproduce every request's token
stream exactly — same tokens, any arrival order, any prompt-length mix,
any slot count — and admission must prefill only the admitted slot
(batch-1 prefills, one per request; never a full-batch re-prefill).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduced
from repro.models import build_model
from repro.serving import Request, ServingEngine, make_serve_step


def build(arch="yi-6b", layers=1, key=0):
    cfg = reduced(REGISTRY[arch], layers=layers)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(key))


def gold_decode(model, params, prompt, max_new, max_seq, eos=None):
    """Isolated one-shot greedy decode: the parity reference."""
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, max_seq))(
        params, {"tokens": jnp.asarray(prompt[None])})
    out = [int(jnp.argmax(logits[0, -1]))]
    step = jax.jit(make_serve_step(model))
    pos = len(prompt)
    while len(out) < max_new and pos < max_seq - 1 \
            and (eos is None or out[-1] != eos):
        nxt, _, cache = step(params, cache,
                             jnp.asarray([[out[-1]]], jnp.int32),
                             jnp.int32(pos))
        out.append(int(nxt[0, 0]))
        pos += 1
    return out


STAGGERED = [  # (prompt, max_new, submit_after_tick)
    (np.arange(1, 4, dtype=np.int32), 6, 0),
    (np.arange(5, 14, dtype=np.int32), 8, 0),     # longer prompt, same wave
    (np.array([9, 8, 7, 6, 5], np.int32), 5, 2),  # arrives mid-decode
    (np.array([2, 2], np.int32), 7, 4),           # late, shortest prompt
]


def run_staggered(model, params, slots, max_seq=64, plan=STAGGERED, **kw):
    eng = ServingEngine(model, params, slots=slots, max_seq=max_seq, **kw)
    pending = sorted(enumerate(plan), key=lambda x: x[1][2])
    tick = 0
    busy = True
    while busy or pending:
        while pending and pending[0][1][2] <= tick:
            uid, (prompt, max_new, _) = pending.pop(0)
            eng.submit(Request(uid, prompt, max_new))
        busy = eng.tick()
        tick += 1
    return eng


@pytest.mark.parametrize("slots", [1, 2, 3])
def test_staggered_arrivals_match_one_shot_decode(slots):
    """The tentpole guarantee: continuous batching is token-identical to
    per-request isolated greedy decode, at every slot count."""
    cfg, model, params = build()
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    eng = run_staggered(model, params, slots)
    got = {r.uid: r.out_tokens for r in eng.done}
    assert len(got) == len(STAGGERED)
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"slots={slots} uid={uid}"


def test_admission_prefills_only_the_admitted_slot():
    """No full-batch re-prefill: one batch-1 prefill per request, O(prompt)
    tokens each, even when other slots are mid-decode at admission."""
    cfg, model, params = build()
    eng = run_staggered(model, params, slots=2)
    assert eng.prefill_batch_sizes == [1] * len(STAGGERED)
    bucket = eng.prefill_bucket
    for toks, (prompt, _, _) in zip(
            eng.prefill_token_counts,
            sorted(STAGGERED, key=lambda x: x[2])):
        assert toks <= -(-len(prompt) // bucket) * bucket


def test_slot_cache_survives_retirement_and_readmission():
    """A freed slot's next occupant decodes correctly (the admission
    scatter fully replaces the slot's cache rows and recurrent state)."""
    cfg, model, params = build()
    prompts = [np.arange(1, 4 + i, dtype=np.int32) for i in range(5)]
    golds = [gold_decode(model, params, p, 4, 48) for p in prompts]
    eng = ServingEngine(model, params, slots=2, max_seq=48)
    for uid, p in enumerate(prompts):           # 5 requests through 2 slots
        eng.submit(Request(uid, p, 4))
    done = {r.uid: r.out_tokens for r in eng.run()}
    for uid, gold in enumerate(golds):
        assert done[uid] == gold


def test_eos_retires_one_slot_without_stalling_others():
    """Slot-level EOS retirement: the EOS'd request stops at its EOS token
    while the other slot's stream is unaffected (still gold-identical)."""
    cfg, model, params = build()
    p0 = np.arange(1, 4, dtype=np.int32)
    p1 = np.arange(5, 10, dtype=np.int32)
    g0 = gold_decode(model, params, p0, 8, 64)
    eos = g0[2]                                  # force EOS three tokens in
    g0_eos = gold_decode(model, params, p0, 8, 64, eos=eos)
    g1 = gold_decode(model, params, p1, 8, 64)
    eng = ServingEngine(model, params, slots=2, max_seq=64)
    eng.submit(Request(0, p0, 8, eos_token=eos))
    eng.submit(Request(1, p1, 8))
    done = {r.uid: r.out_tokens for r in eng.run()}
    assert done[0] == g0_eos and done[0][-1] == eos and len(done[0]) == 3
    assert done[1] == g1


def test_parity_with_prompts_longer_than_local_window():
    """Sliding-window regression: a padded prompt must never spill past
    the attn_local ring (pad k/v would evict real in-window tokens), and
    a prompt longer than the window itself must wrap the ring exactly as
    the gold one-shot prefill does."""
    cfg, model, params = build("gemma2-9b", layers=2, key=1)
    window = min(64, cfg.window_size)
    eng0 = ServingEngine(model, params, slots=2, max_seq=64)
    assert eng0.prefill_bucket > 1          # the bucketed path is under test
    prompts = [
        np.arange(1, 2 + window, dtype=np.int32),       # window + 1 tokens
        np.arange(2, 6 + window, dtype=np.int32),       # window + 4 tokens
        np.arange(3, window, dtype=np.int32),           # pads up to window
    ]
    golds = [gold_decode(model, params, p, 5, 64) for p in prompts]
    eng = ServingEngine(model, params, slots=2, max_seq=64)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, 5))
    done = {r.uid: r.out_tokens for r in eng.run()}
    for uid, gold in enumerate(golds):
        assert done[uid] == gold, f"uid={uid}"
    for toks, p in zip(eng.prefill_token_counts, prompts):
        assert len(p) <= toks <= max(len(p), window)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b", "xlstm-125m",
                                  "qwen2-moe-a2.7b", "gemma2-9b"])
def test_parity_across_families(arch):
    """Hybrid (attn+mamba+moe), pure-SSM, MoE, and local-window attention
    families all hold the parity guarantee (recurrent state and expert
    routing ride the same slot-scatter admission path)."""
    cfg, model, params = build(
        arch, layers=len(REGISTRY[arch].block_pattern), key=1)
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    eng = run_staggered(model, params, slots=2)
    got = {r.uid: r.out_tokens for r in eng.done}
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"{arch} uid={uid}"


# ---------------------------------------------------------------------------
# plan-driven engines (ServingPlan: chunked prefill as plan stages +
# spatial decode replicas) — same gold standard, same guarantee
# ---------------------------------------------------------------------------

def run_plan_staggered(model, params, plan, *, slots, chunk, max_seq=64,
                       sched=STAGGERED, **kw):
    from repro.plan import lower_serving
    splan = lower_serving(plan, slots=slots, chunk=chunk)
    eng = ServingEngine(model, params, slots=slots, max_seq=max_seq,
                        plan=splan, **kw)
    pending = sorted(enumerate(sched), key=lambda x: x[1][2])
    tick = 0
    busy = True
    while busy or pending:
        while pending and pending[0][1][2] <= tick:
            uid, (prompt, max_new, _) = pending.pop(0)
            eng.submit(Request(uid, prompt, max_new))
        busy = eng.tick()
        tick += 1
    return eng


def _uneven_searched_plan(layers=4, n_microbatches=2):
    """An uneven EA/DSE-searched plan on a reduced yi-6b (stage slices
    [3, 1] through the customization pass), plus its model + params."""
    from repro.configs import ShapeConfig
    from repro.core import build_graph, evolutionary_search, ssr_dse
    from repro.plan import lower

    cfg = reduced(REGISTRY["yi-6b"], layers=layers)
    g = build_graph(cfg, ShapeConfig("t", 16, 8, "prefill"))
    res = evolutionary_search(g, 8, n_acc=2, n_batches=2, n_pop=6,
                              n_child=6, n_iter=3, seed=1)
    plan = lower(res.assignment, g, mesh_devices=8,
                 n_microbatches=n_microbatches)
    if plan.is_uniform:
        # the EA may legitimately collapse a uniform dense stack; force the
        # guaranteed-uneven DSE cut so the padded/masked stage path is hit
        _, _, assign = ssr_dse(g, (0, 0, 0, 0, 1, 1), 8, n_batches=2)
        plan = lower(assign, g, mesh_devices=8,
                     n_microbatches=n_microbatches)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(0)), plan


@pytest.mark.parametrize("slots", [2, 3])
def test_plan_staggered_parity_uniform_2stage(slots):
    """The plan-driven tentpole guarantee: staggered arrivals through a
    uniform 2-stage ServingPlan (chunked prefill engaged, 2 decode
    replicas) are token-identical to isolated one-shot decode."""
    from repro.plan import uniform_plan
    cfg, model, params = build(layers=4)
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    plan = uniform_plan(cfg.num_groups, 2, n_microbatches=2)
    eng = run_plan_staggered(model, params, plan, slots=slots, chunk=4)
    got = {r.uid: r.out_tokens for r in eng.done}
    assert len(got) == len(STAGGERED)
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"slots={slots} uid={uid}"
    # chunked prefill ran: the 9-token prompt streamed as ceil(9/4) chunks
    assert eng.prefill_chunk_counts == [1, 3, 2, 1]
    assert eng.prefill_token_counts == [3, 9, 5, 2]   # exact, no padding
    assert eng.prefill_batch_sizes == [1] * len(STAGGERED)


def test_plan_staggered_parity_uneven_searched_plan():
    """Staggered arrivals through an uneven EA-searched plan (stage
    slices [3, 1]) are token-identical to isolated one-shot decode."""
    cfg, model, params, plan = _uneven_searched_plan()
    assert not plan.is_uniform
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    eng = run_plan_staggered(model, params, plan, slots=3, chunk=4)
    got = {r.uid: r.out_tokens for r in eng.done}
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"uid={uid}"


def test_plan_replica_partition_and_reuse():
    """Slots partition over the spatial decode replicas; a retired slot's
    next occupant (possibly in a different replica) decodes correctly —
    the admission scatter fully replaces the replica-local slot rows."""
    from repro.plan import uniform_plan
    cfg, model, params = build(layers=4)
    prompts = [np.arange(1, 4 + i, dtype=np.int32) for i in range(6)]
    golds = [gold_decode(model, params, p, 4, 48) for p in prompts]
    plan = uniform_plan(cfg.num_groups, 2, n_microbatches=2)
    from repro.plan import lower_serving
    splan = lower_serving(plan, slots=4, chunk=4)
    assert splan.replica_slots == (2, 2)
    eng = ServingEngine(model, params, slots=4, max_seq=48, plan=splan)
    for uid, p in enumerate(prompts):           # 6 requests through 4 slots
        eng.submit(Request(uid, p, 4))
    done = {r.uid: r.out_tokens for r in eng.run()}
    for uid, gold in enumerate(golds):
        assert done[uid] == gold, f"uid={uid}"
    slots_used = {r.slot for r in eng.done}
    assert slots_used == {0, 1, 2, 3}           # both replicas served


def test_chunked_prefill_never_stalls_decode():
    """Chunked-prefill admission is interleaved with decode: while a long
    prompt streams through the stages (one stage-step per tick), the
    already-active slot keeps emitting a token EVERY tick — and both
    streams stay gold-identical."""
    from repro.plan import lower_serving, uniform_plan
    cfg, model, params = build(layers=4)
    p0 = np.array([5, 6, 7], np.int32)
    p1 = np.arange(1, 14, dtype=np.int32)       # 13 tokens -> 4+ chunks
    g0 = gold_decode(model, params, p0, 12, 64)
    g1 = gold_decode(model, params, p1, 6, 64)
    plan = uniform_plan(cfg.num_groups, 2, n_microbatches=1)
    eng = ServingEngine(model, params, slots=2, max_seq=64,
                        plan=lower_serving(plan, slots=2, chunk=4))
    eng.submit(Request(0, p0, 12))
    while eng._slot_req[0] is None:             # admit + finish prefill 0
        eng.tick()
    eng.submit(Request(1, p1, 6))
    n0 = len(eng._slot_req[0].out_tokens)
    ticks = 0
    while 1 in eng._reserved or eng._slot_req[1] is None:
        eng.tick()
        ticks += 1
        if eng._slot_req[0] is not None:
            # one decode token per tick, even mid-chunked-prefill
            assert len(eng._slot_req[0].out_tokens) == n0 + ticks
    assert ticks > 2                            # prefill really spanned ticks
    done = {r.uid: r.out_tokens for r in eng.run()}
    assert done[0] == g0 and done[1] == g1
    assert eng.prefill_chunk_counts == [1, 4]


# ---------------------------------------------------------------------------
# paged engines (block-pool slot caches + prefix sharing) — same gold
# standard, same guarantee, both cache layouts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("slots", [1, 2, 3])
def test_paged_staggered_parity(slots):
    """The paged tentpole guarantee: staggered Poisson-style arrivals
    through a block-pool cache (page 4 — every prompt spans blocks) are
    token-identical to isolated one-shot decode at every slot count."""
    cfg, model, params = build()
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    eng = run_staggered(model, params, slots, paged=True, page_size=4)
    assert eng.paged and eng.cache_stats()["layout"] == "paged"
    got = {r.uid: r.out_tokens for r in eng.done}
    assert len(got) == len(STAGGERED)
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"slots={slots} uid={uid}"


@pytest.mark.parametrize("arch,layers", [("gemma2-9b", 2),
                                         ("jamba-1.5-large-398b", 8)])
def test_paged_parity_mixed_layouts(arch, layers):
    """Families where paging applies per layer: gemma2 pages its global
    layers while the local-window rings stay dense; jamba pages its one
    attn layer while mamba state stays dense — parity holds throughout."""
    cfg, model, params = build(arch, layers=layers, key=1)
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    eng = run_staggered(model, params, slots=2, paged=True, page_size=4)
    assert eng.paged
    got = {r.uid: r.out_tokens for r in eng.done}
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"{arch} uid={uid}"


def test_paged_auto_disables_without_global_attention():
    """An SSM-only model has no pageable KV: the engine falls back to the
    dense layout wholesale (and still holds parity)."""
    cfg, model, params = build("xlstm-125m", layers=4, key=1)
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    eng = run_staggered(model, params, slots=2, paged=True, page_size=4)
    assert not eng.paged and eng.cache_stats()["layout"] == "dense"
    got = {r.uid: r.out_tokens for r in eng.done}
    for uid, gold in enumerate(golds):
        assert got[uid] == gold


def test_paged_prefix_sharing_refcount_then_copy_on_write():
    """The acceptance scenario: two prompts sharing a >= 1-block prefix
    provably share physical blocks (refcount observed) — including a
    partial-tail share — until the second stream's first divergent decode
    write copy-on-writes; both streams stay gold-identical."""
    cfg, model, params = build()
    p1 = np.arange(1, 9, dtype=np.int32)       # 2 full blocks at page 4
    p2 = p1[:6].copy()                         # full block + partial tail
    g1 = gold_decode(model, params, p1, 5, 32)
    g2 = gold_decode(model, params, p2, 5, 32)
    eng = ServingEngine(model, params, slots=2, max_seq=32, paged=True,
                        page_size=4)
    pool = eng._pager.pool
    eng.submit(Request(0, p1, 5))
    eng.tick()                                 # admit + first decode of p1
    eng.submit(Request(1, p2, 5))
    eng._admit()                               # map p2 before any decode
    t0 = eng._pager.tables[0].blocks.copy()
    t1 = eng._pager.tables[1].blocks.copy()
    assert t0[0] == t1[0] and pool.refcount[t0[0]] == 2   # full block shared
    assert t0[1] == t1[1] and pool.refcount[t0[1]] == 2   # tail shared
    assert pool.cow_copies == 0
    eng.tick()                   # p2's first decode write diverges: COW
    assert pool.cow_copies == 1
    assert eng._pager.tables[1].blocks[1] != t0[1]
    assert pool.refcount[t0[1]] == 1           # back to p1's exclusive ref
    assert pool.refcount[t0[0]] == 2           # full block still shared
    done = {r.uid: r.out_tokens for r in eng.run()}
    assert done[0] == g1 and done[1] == g2
    st = eng.cache_stats()
    assert st["prefix_hits"] >= 2 and st["cow_copies"] == 1


def test_paged_plan_reserved_slot_cannot_corrupt_shared_blocks():
    """Regression: in plan mode a slot is mapped at admission but only
    activated ticks later (chunked prefill) — meanwhile it rides the
    replica's decode batch with a stale position.  Its block-table row
    must stay unmapped until commit, or the stale write would corrupt a
    shared registered block that the *other* stream is attending."""
    from repro.plan import lower_serving, uniform_plan
    cfg, model, params = build(layers=4)
    pa = np.arange(1, 9, dtype=np.int32)            # 2 full blocks at page 4
    pb = np.concatenate([pa, [30, 31]]).astype(np.int32)  # shares both
    ga = gold_decode(model, params, pa, 12, 64)
    gb = gold_decode(model, params, pb, 6, 64)
    plan = uniform_plan(cfg.num_groups, 2, n_microbatches=1)
    eng = ServingEngine(model, params, slots=2, max_seq=64,
                        plan=lower_serving(plan, slots=2, chunk=4),
                        paged=True, page_size=4)
    eng.submit(Request(0, pa, 12))
    while eng._slot_req[0] is None:                 # A through prefill
        eng.tick()
    eng.submit(Request(1, pb, 6))
    pool = eng._pager.pool
    saw_shared_mid_prefill = False
    while 1 in eng._reserved or eng._slot_req[1] is None:
        eng.tick()                                  # A decodes every tick
        if 1 in eng._reserved:
            # B's prefix blocks are refcounted already, but its table
            # row must stay unmapped while it rides A's decode batch
            assert eng._pager.tables[1].n_mapped == 0
            if pool.refcount[eng._pager.tables[0].blocks[0]] == 2:
                saw_shared_mid_prefill = True
    assert saw_shared_mid_prefill                   # sharing really engaged
    done = {r.uid: r.out_tokens for r in eng.run()}
    assert done[0] == ga and done[1] == gb


def test_paged_decode_growth_reserved_no_mid_stream_exhaustion():
    """Regression: admission reserves worst-case decode growth, so an
    undersized pool defers admissions instead of raising PoolExhausted
    mid-stream when slots grow past their prompt blocks."""
    cfg, model, params = build()
    prompts = [np.arange(1, 9, dtype=np.int32),
               np.arange(20, 28, dtype=np.int32)]
    golds = [gold_decode(model, params, p, 9, 32) for p in prompts]
    # each request needs ceil((8+9)/4) = 5 blocks; 6 < 10 forces serial
    eng = ServingEngine(model, params, slots=2, max_seq=32, paged=True,
                        page_size=4, num_blocks=6)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, 9))
    done = {r.uid: r.out_tokens for r in eng.run()}
    for uid, gold in enumerate(golds):
        assert done[uid] == gold, f"uid={uid}"
    assert eng.cache_stats()["peak_blocks_in_use"] <= 6


def test_paged_request_that_can_never_fit_raises_with_sizing_message():
    """A request whose prompt + budget exceeds the whole pool must fail
    loudly at admission (deferral would deadlock the queue)."""
    from repro.cache import PoolExhausted
    cfg, model, params = build()
    eng = ServingEngine(model, params, slots=2, max_seq=32, paged=True,
                        page_size=4, num_blocks=4)
    eng.submit(Request(0, np.arange(1, 9, dtype=np.int32), 9))  # 5 blocks
    with pytest.raises(PoolExhausted, match="num_blocks"):
        eng.run()


def test_paged_small_pool_admission_defers_not_breaks():
    """A pool smaller than the dense reservation forces admissions to
    wait for blocks; every stream still retires gold-identical (deferral
    is scheduling, and scheduling cannot change tokens)."""
    cfg, model, params = build()
    prompts = [np.arange(1 + 3 * i, 8 + 3 * i, dtype=np.int32)
               for i in range(4)]
    golds = [gold_decode(model, params, p, 4, 32) for p in prompts]
    eng = ServingEngine(model, params, slots=2, max_seq=32, paged=True,
                        page_size=4, num_blocks=6)    # dense would need 16
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, 4))
    done = {r.uid: r.out_tokens for r in eng.run()}
    assert len(done) == 4
    for uid, gold in enumerate(golds):
        assert done[uid] == gold, f"uid={uid}"
    assert eng.cache_stats()["peak_blocks_in_use"] <= 6


@pytest.mark.parametrize("slots", [2, 3])
def test_paged_plan_replica_parity(slots):
    """Paged caches compose with plan-driven serving: each decode replica
    owns a partition of the block pool; chunked prefill + stage-walk
    decode over paged replica caches stay token-identical to one-shot
    decode."""
    from repro.plan import uniform_plan
    cfg, model, params = build(layers=4)
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    plan = uniform_plan(cfg.num_groups, 2, n_microbatches=2)
    eng = run_plan_staggered(model, params, plan, slots=slots, chunk=4,
                             paged=True, page_size=4)
    assert eng.paged and len(eng._all_pagers()) == 1
    assert eng._pager.slots == slots                # global slot ids
    got = {r.uid: r.out_tokens for r in eng.done}
    assert len(got) == len(STAGGERED)
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"slots={slots} uid={uid}"


def test_paged_plan_uneven_searched_plan_parity():
    """Paged + an uneven EA-searched ServingPlan (stage slices [3, 1])."""
    cfg, model, params, plan = _uneven_searched_plan()
    assert not plan.is_uniform
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    eng = run_plan_staggered(model, params, plan, slots=3, chunk=4,
                             paged=True, page_size=4)
    got = {r.uid: r.out_tokens for r in eng.done}
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"uid={uid}"


@pytest.mark.slow
@pytest.mark.parametrize("arch,layers", [("jamba-1.5-large-398b", 16),
                                         ("xlstm-125m", 8),
                                         ("gemma2-9b", 4)])
def test_paged_plan_parity_across_families(arch, layers):
    """Paged plan-replica engines across the hybrid / pure-SSM /
    local-window families (paging auto-disables per layer or wholesale as
    the pattern dictates)."""
    from repro.plan import uniform_plan
    cfg, model, params = build(arch, layers=layers, key=1)
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    plan = uniform_plan(cfg.num_groups, 2, n_microbatches=2)
    eng = run_plan_staggered(model, params, plan, slots=2, chunk=4,
                             paged=True, page_size=4)
    got = {r.uid: r.out_tokens for r in eng.done}
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"{arch} uid={uid}"


# ---------------------------------------------------------------------------
# prefix COMPUTE reuse (suffix-only prefill on warm prefixes) — same gold
# standard: a warm engine must emit exactly what a cold engine emits
# ---------------------------------------------------------------------------

WARM_SEED = np.arange(1, 9, dtype=np.int32)        # 2 full 4-token blocks

WARM_CASES = [  # (warm prompt, expected reused_tokens at page 4)
    # extends the seed: both full blocks warm, 2-token suffix
    (np.concatenate([WARM_SEED, [30, 31]]).astype(np.int32), 8),
    # partial-tail match: 1 full block + 2 rows of the seed's second
    # block; only the last token (capped at L-1) recomputes
    (WARM_SEED[:6].copy(), 5),
    # identical prompt: everything warm except the forced last token
    (WARM_SEED.copy(), 7),
]


def test_warm_prefix_suffix_only_parity_monolithic():
    """A retired request's parked blocks make later admissions warm: each
    WARM_CASE reports its exact reused-token count, prefills only the
    suffix, and the token stream equals a cold one-shot decode —
    full-block matches, partial-tail matches, and the identical prompt
    (whose one recomputed token's page write must drop on the shared
    block)."""
    cfg, model, params = build()
    golds = [gold_decode(model, params, p, 6, 64) for p, _ in WARM_CASES]
    eng = ServingEngine(model, params, slots=2, max_seq=64, paged=True,
                        page_size=4, prefill_bucket=4)
    eng.submit(Request(99, WARM_SEED.copy(), 4))
    eng.run()                                      # seed retires: blocks park
    eng.reset_stats()
    for uid, (p, _) in enumerate(WARM_CASES):
        eng.submit(Request(uid, p.copy(), 6))
    done = {r.uid: r.out_tokens for r in eng.run()}
    for uid, gold in enumerate(golds):
        assert done[uid] == gold, f"warm case {uid}"
    st = eng.cache_stats()
    assert st["prefill_compute_hits"] == len(WARM_CASES)
    assert st["reused_prefill_tokens"] == sum(r for _, r in WARM_CASES)
    # suffix-only: no warm admission prefilled more than its suffix
    # rounded to the bucket (never the whole prompt re-padded)
    for toks, (p, reused) in zip(eng.prefill_token_counts, WARM_CASES):
        assert toks <= -(-(len(p) - reused) // 4) * 4


@pytest.mark.parametrize("chunk", [4, 16])
def test_warm_prefix_suffix_only_parity_plan(chunk):
    """Plan-driven warm admissions: the suffix streams through the plan's
    stages — chunked (chunk=4: a 10-token suffix is 3 chunks) and
    whole-prompt (chunk=16: one chunk) — writing pool pages as chunks
    complete, token-identical to cold one-shot decode."""
    from repro.plan import lower_serving, uniform_plan
    cfg, model, params = build(layers=4)
    warm = np.concatenate([WARM_SEED,
                           np.arange(30, 40)]).astype(np.int32)
    gold = gold_decode(model, params, warm, 6, 64)
    plan = uniform_plan(cfg.num_groups, 2, n_microbatches=1)
    eng = ServingEngine(model, params, slots=2, max_seq=64,
                        plan=lower_serving(plan, slots=2, chunk=chunk),
                        paged=True, page_size=4)
    eng.submit(Request(0, WARM_SEED.copy(), 4))
    eng.run()                                      # seed retires: blocks park
    eng.submit(Request(1, warm.copy(), 6))
    done = {r.uid: r.out_tokens for r in eng.run()}
    assert done[1] == gold
    pool = eng._pager.pool
    assert pool.prefill_compute_hits == 1
    assert pool.reused_prefill_tokens == 8
    # the warm admission chunked only its 10-token suffix
    assert eng.prefill_token_counts[-1] == len(warm) - 8
    assert eng.prefill_chunk_counts[-1] == (3 if chunk == 4 else 1)


def test_chunked_prefill_publishes_blocks_mid_prompt_for_reuse():
    """Chunks write pool pages as they complete, so a prompt still
    mid-prefill already feeds the compute cache: a second request sharing
    its prefix admits warm BEFORE the first finishes — both streams stay
    gold-identical."""
    from repro.plan import lower_serving, uniform_plan
    cfg, model, params = build(layers=4)
    pa = np.arange(1, 13, dtype=np.int32)          # 12 tokens = 3 chunks
    pb = np.concatenate([pa[:8], [50, 51]]).astype(np.int32)
    ga = gold_decode(model, params, pa, 6, 64)
    gb = gold_decode(model, params, pb, 6, 64)
    plan = uniform_plan(cfg.num_groups, 2, n_microbatches=1)
    eng = ServingEngine(model, params, slots=2, max_seq=64,
                        plan=lower_serving(plan, slots=2, chunk=4),
                        paged=True, page_size=4)
    eng.submit(Request(0, pa, 6))
    pool = eng._pager.pool
    while not pool.registry:                       # first chunk publishes
        assert eng.tick()
    assert 0 in eng._reserved                      # A still mid-prefill
    eng.submit(Request(1, pb, 6))
    done = {r.uid: r.out_tokens for r in eng.run()}
    assert done[0] == ga and done[1] == gb
    assert pool.prefill_compute_hits >= 1          # B admitted warm
    assert pool.reused_prefill_tokens >= 4


def test_warm_prefix_memory_shares_without_compute_reuse_for_hybrids():
    """Families whose prefill is not suffix-decomposable (here: jamba's
    mamba blocks) keep block-level MEMORY sharing but never skip
    compute: reused tokens stay zero, parity still holds."""
    cfg, model, params = build("jamba-1.5-large-398b", layers=8, key=1)
    p = np.arange(1, 9, dtype=np.int32)
    gold = gold_decode(model, params, p, 5, 64)
    eng = ServingEngine(model, params, slots=2, max_seq=64, paged=True,
                        page_size=4)
    assert not eng._suffix_reuse
    eng.submit(Request(0, p, 5))
    eng.run()
    eng.submit(Request(1, p.copy(), 5))
    done = {r.uid: r.out_tokens for r in eng.run()}
    assert done[1] == gold
    st = eng.cache_stats()
    assert st["prefix_hits"] >= 2                  # memory sharing engaged
    assert st["prefill_compute_hits"] == 0         # compute reuse gated off
    assert st["reused_prefill_tokens"] == 0


@pytest.mark.slow
@pytest.mark.parametrize("arch,layers", [("jamba-1.5-large-398b", 16),
                                         ("xlstm-125m", 8),
                                         ("gemma2-9b", 4)])
def test_plan_parity_across_families(arch, layers):
    """Hybrid (attn+mamba+moe — chunking auto-disabled by the MoE gate,
    whole-prompt stage walk), pure-SSM (chunked: split scans are exact),
    and local-window families hold the guarantee through a 2-stage plan
    with 2 decode replicas."""
    from repro.plan import uniform_plan
    cfg, model, params = build(arch, layers=layers, key=1)
    assert cfg.num_groups % 2 == 0
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    plan = uniform_plan(cfg.num_groups, 2, n_microbatches=2)
    eng = run_plan_staggered(model, params, plan, slots=2, chunk=4)
    got = {r.uid: r.out_tokens for r in eng.done}
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"{arch} uid={uid}"
    if any(b.ffn == "moe" for b in cfg.block_pattern):
        # MoE capacity is per-call: chunking must have auto-disabled
        assert eng.prefill_chunk_counts == [1] * len(STAGGERED)

# ---------------------------------------------------------------------------
# speculative decode (prompt-lookup drafting + batched greedy verify) —
# same gold standard: speculation must be a pure latency optimisation
# ---------------------------------------------------------------------------

SPEC_PROMPTS = [  # repetitive prompts so the n-gram drafter engages
    (np.array([5, 6, 7, 5, 6, 7, 5, 6], np.int32), 12, 0),
    (np.array([1, 2, 1, 2, 1, 2, 1], np.int32), 12, 0),
    (np.array([9, 8, 9, 8, 9, 8], np.int32), 10, 2),
    (np.array([3, 3, 3, 3, 3], np.int32), 8, 3),
]


@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("speculate", [2, 4])
def test_speculative_parity_monolithic(paged, speculate):
    """Greedy verify accepts a drafted token only where it equals what
    plain decode would emit, so every stream is bit-identical to the
    one-shot gold decode — while covering >1 token per decode step."""
    cfg, model, params = build()
    golds = [gold_decode(model, params, p, mn, 64)
             for p, mn, _ in SPEC_PROMPTS]
    kw = {"paged": True, "page_size": 4} if paged else {}
    eng = run_staggered(model, params, slots=2, plan=SPEC_PROMPTS,
                        speculate=speculate, **kw)
    got = {r.uid: r.out_tokens for r in eng.done}
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"paged={paged} K={speculate} uid={uid}"
    st = eng.stats()
    assert st["spec_steps"] > 0                  # speculation engaged ...
    assert st["spec_accepted"] > 0
    assert st["tokens_per_step"] > 1.0           # ... and paid off
    assert st["acceptance_rate"] > 0.0


@pytest.mark.parametrize("paged", [False, True])
def test_speculative_parity_plan_replicas(paged):
    """Plan-driven engines verify per spatial replica (each replica's
    slot partition scores its own (K+1)-token window) and stay
    gold-identical with speculation on."""
    from repro.plan import uniform_plan
    cfg, model, params = build(layers=4)
    golds = [gold_decode(model, params, p, mn, 64)
             for p, mn, _ in SPEC_PROMPTS]
    plan = uniform_plan(cfg.num_groups, 2, n_microbatches=2)
    kw = {"paged": True, "page_size": 4} if paged else {}
    eng = run_plan_staggered(model, params, plan, slots=4, chunk=4,
                             sched=SPEC_PROMPTS, speculate=3, **kw)
    got = {r.uid: r.out_tokens for r in eng.done}
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"paged={paged} uid={uid}"
    st = eng.stats()
    assert st["spec_steps"] > 0
    assert st["tokens_per_step"] > 1.0


def test_speculative_eos_mid_window_stops_stream():
    """EOS emitted from inside an accepted window retires the request at
    the EOS token: no accepted-but-past-EOS tokens leak into the output,
    and the other slot's stream is unaffected."""
    cfg, model, params = build()
    p0, mn0 = SPEC_PROMPTS[0][0], SPEC_PROMPTS[0][1]
    g0 = gold_decode(model, params, p0, mn0, 64)
    eos = g0[3]                                  # force EOS four tokens in
    g0_eos = gold_decode(model, params, p0, mn0, 64, eos=eos)
    p1, mn1 = SPEC_PROMPTS[1][0], SPEC_PROMPTS[1][1]
    g1 = gold_decode(model, params, p1, mn1, 64)
    eng = ServingEngine(model, params, slots=2, max_seq=64, speculate=4,
                        paged=True, page_size=4)
    eng.submit(Request(0, p0, mn0, eos_token=eos))
    eng.submit(Request(1, p1, mn1))
    done = {r.uid: r.out_tokens for r in eng.run()}
    assert done[0] == g0_eos and done[0][-1] == eos
    assert done[1] == g1


def test_speculative_rollback_keeps_pool_consistent():
    """After a speculative run every rejected-tail block went back to the
    pool: retiring all requests leaves zero blocks in use and the freed
    capacity re-admits a fresh request that still decodes gold."""
    cfg, model, params = build()
    eng = run_staggered(model, params, slots=2, plan=SPEC_PROMPTS,
                        speculate=4, paged=True, page_size=4)
    assert eng.stats()["spec_steps"] > 0
    pool = eng._pager.pool
    assert pool.blocks_in_use == 0
    p = np.array([7, 7, 7, 7], np.int32)
    gold = gold_decode(model, params, p, 6, 64)
    eng.submit(Request(9, p, 6))
    eng.run()
    assert {r.uid: r.out_tokens for r in eng.done}[9] == gold


def test_prefill_token_counts_match_plan_engine_unpadded():
    """Monolithic engines report UNPADDED prompt tokens per admission —
    identical to the plan engine's per-chunk exact accounting over the
    same schedule (the jit pad bucket is a shape artifact, not work)."""
    from repro.plan import uniform_plan
    cfg, model, params = build(layers=4)
    mono = run_staggered(model, params, slots=2)
    assert mono.prefill_token_counts == [3, 9, 5, 2]
    plan = uniform_plan(cfg.num_groups, 2, n_microbatches=2)
    peng = run_plan_staggered(model, params, plan, slots=2, chunk=4)
    assert mono.prefill_token_counts == peng.prefill_token_counts


# ---------------------------------------------------------------------------
# async overlapped runtime (one-step-delayed drain) — same gold standard:
# overlap changes WHEN the host reads tokens back, never WHICH tokens
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True])
def test_overlap_staggered_parity_monolithic(paged):
    """Async engine ≡ sync engine ≡ isolated one-shot gold, dense and
    paged: dispatching step N+1 before draining step N only re-times the
    host readback (the device-side token chain feeds each next step)."""
    cfg, model, params = build()
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    kw = {"paged": True, "page_size": 4} if paged else {}
    sync = run_staggered(model, params, slots=2, **kw)
    eng = run_staggered(model, params, slots=2, overlap=True, **kw)
    assert eng._overlap                          # overlap really engaged
    got = {r.uid: r.out_tokens for r in eng.done}
    ref = {r.uid: r.out_tokens for r in sync.done}
    assert len(got) == len(STAGGERED)
    for uid, gold in enumerate(golds):
        assert ref[uid] == gold, f"sync paged={paged} uid={uid}"
        assert got[uid] == gold, f"async paged={paged} uid={uid}"


@pytest.mark.parametrize("paged", [False, True])
def test_overlap_staggered_parity_plan(paged):
    """Async ≡ sync ≡ gold through plan-driven engines: per-replica
    decode dispatch chains on-device while chunked prefill streams the
    stages, dense and paged replica caches alike."""
    from repro.plan import uniform_plan
    cfg, model, params = build(layers=4)
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    plan = uniform_plan(cfg.num_groups, 2, n_microbatches=2)
    kw = {"paged": True, "page_size": 4} if paged else {}
    sync = run_plan_staggered(model, params, plan, slots=3, chunk=4, **kw)
    eng = run_plan_staggered(model, params, plan, slots=3, chunk=4,
                             overlap=True, **kw)
    assert eng._overlap
    got = {r.uid: r.out_tokens for r in eng.done}
    ref = {r.uid: r.out_tokens for r in sync.done}
    for uid, gold in enumerate(golds):
        assert ref[uid] == gold, f"sync paged={paged} uid={uid}"
        assert got[uid] == gold, f"async paged={paged} uid={uid}"


def test_overlap_eos_and_budget_retire_exact():
    """Delayed drain retires slots at most one tick late but never emits
    past EOS or past the token budget: streams match the sync engine's
    EOS-truncated gold exactly."""
    cfg, model, params = build()
    p0 = np.arange(1, 4, dtype=np.int32)
    p1 = np.arange(5, 10, dtype=np.int32)
    g0 = gold_decode(model, params, p0, 8, 64)
    eos = g0[2]                                  # force EOS three tokens in
    g0_eos = gold_decode(model, params, p0, 8, 64, eos=eos)
    g1 = gold_decode(model, params, p1, 8, 64)
    eng = ServingEngine(model, params, slots=2, max_seq=64, overlap=True,
                        paged=True, page_size=4)
    eng.submit(Request(0, p0, 8, eos_token=eos))
    eng.submit(Request(1, p1, 8))
    done = {r.uid: r.out_tokens for r in eng.run()}
    assert done[0] == g0_eos and done[0][-1] == eos and len(done[0]) == 3
    assert done[1] == g1


def test_overlap_retirement_and_readmission():
    """A slot freed by a delayed drain re-admits correctly: the next
    occupant's stream ignores any garbage in-flight step the retired
    request left behind (exclusively-owned pages, then a full admission
    re-map)."""
    cfg, model, params = build()
    prompts = [np.arange(1, 4 + i, dtype=np.int32) for i in range(5)]
    golds = [gold_decode(model, params, p, 4, 48) for p in prompts]
    for kw in ({}, {"paged": True, "page_size": 4}):
        eng = ServingEngine(model, params, slots=2, max_seq=48,
                            overlap=True, **kw)
        for uid, p in enumerate(prompts):       # 5 requests through 2 slots
            eng.submit(Request(uid, p, 4))
        done = {r.uid: r.out_tokens for r in eng.run()}
        for uid, gold in enumerate(golds):
            assert done[uid] == gold, f"kw={kw} uid={uid}"


def test_overlap_with_speculation_falls_back_to_sync():
    """Speculative verify needs drafted tokens on the host before the
    next dispatch, so overlap=True + speculate>0 degrades to the sync
    path — and stays gold-identical."""
    cfg, model, params = build()
    golds = [gold_decode(model, params, p, mn, 64)
             for p, mn, _ in SPEC_PROMPTS]
    eng = run_staggered(model, params, slots=2, plan=SPEC_PROMPTS,
                        overlap=True, speculate=3, paged=True, page_size=4)
    assert not eng._overlap                      # forced sync
    assert eng.stats()["spec_steps"] > 0
    got = {r.uid: r.out_tokens for r in eng.done}
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"uid={uid}"


# ---------------------------------------------------------------------------
# adaptive re-planning: live plan swaps with zero-copy slot migration
# ---------------------------------------------------------------------------

def run_staggered_replans(model, params, *, slots, swaps, max_seq=64,
                          sched=STAGGERED, **kw):
    """Drive the STAGGERED arrival schedule while forcing ``replan`` at
    the given ticks.  ``swaps``: [(tick, ServingPlan-or-None), ...]."""
    eng = ServingEngine(model, params, slots=slots, max_seq=max_seq, **kw)
    pending = sorted(enumerate(sched), key=lambda x: x[1][2])
    swaps = sorted(swaps)
    tick = 0
    busy = True
    while busy or pending or swaps:
        while pending and pending[0][1][2] <= tick:
            uid, (prompt, max_new, _) = pending.pop(0)
            eng.submit(Request(uid, prompt, max_new))
        while swaps and swaps[0][0] <= tick:
            eng.replan(swaps.pop(0)[1])
        busy = eng.tick()
        tick += 1
    return eng


def _ladder(num_groups, slots, chunk=4):
    """mono -> narrow plan -> widest plan candidates for ``slots``."""
    from repro.plan import lower_serving, uniform_plan
    return [
        None,
        lower_serving(uniform_plan(num_groups, 2, n_microbatches=1),
                      slots=slots, chunk=chunk),
        lower_serving(uniform_plan(num_groups, 2, n_microbatches=slots),
                      slots=slots, chunk=chunk),
    ]


@pytest.mark.parametrize("paged", [False, True])
def test_replan_sequence_parity_mono_plan_wider_mono(paged):
    """The tentpole invariant: any re-plan sequence — monolithic -> plan
    -> wider plan -> monolithic, forced mid-traffic across staggered
    arrivals — leaves every token stream identical to isolated one-shot
    greedy decode.  On the paged path the swaps move ZERO KV bytes."""
    cfg, model, params = build(layers=4)
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    mono, narrow, wide = _ladder(cfg.num_groups, slots=3)
    kw = {"paged": True, "page_size": 4} if paged else {}
    eng = run_staggered_replans(
        model, params, slots=3,
        swaps=[(3, narrow), (6, wide), (9, mono)], **kw)
    got = {r.uid: r.out_tokens for r in eng.done}
    assert len(got) == len(STAGGERED)
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"paged={paged} uid={uid}"
    st = eng.stats()
    assert st["replans"] == 3
    assert st["plan_label"] == "mono"            # ended monolithic
    if paged:
        # zero-copy: yi-6b is all-global-attention, so slot moves are
        # pure block-table handoffs — no dense rows, no page copies
        assert st["migration_copies"] == 0
        assert st["cache"]["migrations"] == eng._pager.migrations
    else:
        # dense engines move one batch row per migrated slot, at most
        assert st["migration_copies"] == st["migrations"]


def test_replan_rebalance_migrates_zero_copy_paged():
    """Cross-replica work stealing on the paged path: going monolithic ->
    2-replica plan with both active slots on what becomes replica 0
    forces a migration to replica 1 — a block-table row handoff whose
    pool counters prove no KV moved (no copies, no COW, no alloc/free
    churn), with both streams still gold-identical."""
    from repro.plan import lower_serving, uniform_plan
    cfg, model, params = build(layers=4)
    prompts = [np.arange(1, 6, dtype=np.int32),
               np.arange(20, 29, dtype=np.int32)]
    golds = [gold_decode(model, params, p, 10, 64) for p in prompts]
    eng = ServingEngine(model, params, slots=4, max_seq=64,
                        paged=True, page_size=4)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, 10))
    for _ in range(3):                           # both decode on slots 0, 1
        eng.tick()
    assert [s for s in range(4) if eng._slot_req[s] is not None] == [0, 1]
    pool = eng._pager.pool
    in_use = pool.blocks_in_use
    cow0, evic0 = pool.cow_copies, pool.evictions
    plan = lower_serving(uniform_plan(cfg.num_groups, 2, n_microbatches=2),
                         slots=4, chunk=4)       # partitions [0,1] | [2,3]
    eng.replan(plan)                             # load 2|0 -> steal one
    assert eng.migrations == 1 and eng._pager.migrations == 1
    assert eng.migration_copies == 0             # table handoff only
    assert pool.blocks_in_use == in_use          # no alloc/free churn
    assert pool.cow_copies == cow0 and pool.evictions == evic0
    moved = [s for s in range(4) if eng._slot_req[s] is not None]
    assert len(moved) == 2 and moved[1] >= 2     # one slot now on replica 1
    done = {r.uid: r.out_tokens for r in eng.run()}
    for uid, gold in enumerate(golds):
        assert done[uid] == gold, f"uid={uid}"
    assert eng.stats()["migrations"] == 1


@pytest.mark.parametrize("to_mono", [False, True])
def test_replan_mid_prefill_drains_on_admission_runtime(to_mono):
    """Drain-and-rebind: a re-plan fired while a chunked prefill is
    mid-flight lets the remaining chunks finish on the runtime they were
    admitted under (plan -> wider plan, and plan -> monolithic where the
    old pipeline survives solely to drain) — token streams stay gold."""
    from repro.plan import lower_serving, uniform_plan
    cfg, model, params = build(layers=4)
    pa = np.arange(1, 4, dtype=np.int32)
    pb = np.arange(5, 18, dtype=np.int32)        # 13 tokens = 4 chunks
    ga = gold_decode(model, params, pa, 8, 64)
    gb = gold_decode(model, params, pb, 6, 64)
    narrow = lower_serving(uniform_plan(cfg.num_groups, 2, n_microbatches=1),
                           slots=2, chunk=4)
    wide = lower_serving(uniform_plan(cfg.num_groups, 2, n_microbatches=2),
                         slots=2, chunk=4)
    eng = ServingEngine(model, params, slots=2, max_seq=64, plan=narrow,
                        paged=True, page_size=4)
    eng.submit(Request(0, pa, 8))
    while eng._slot_req[0] is None:              # A active, decoding
        eng.tick()
    eng.submit(Request(1, pb, 6))
    eng.tick()                                   # B mid-prefill now
    assert 1 in eng._reserved
    item = eng._pf.items[0]
    eng.replan(None if to_mono else wide)
    assert (item.rt or eng._rt) is not None      # admission runtime pinned
    if to_mono:
        assert eng.plan is None and eng._pf is not None   # draining only
    done = {r.uid: r.out_tokens for r in eng.run()}
    if to_mono:
        assert eng._pf is None                   # pipeline dropped when dry
    assert done[0] == ga and done[1] == gb
    assert eng.stats()["migration_copies"] == 0


def test_replan_with_speculation_active_stays_gold():
    """A re-plan between speculative verify ticks (drafter engaged, spec
    window mid-stream): mono -> plan -> mono keeps every stream
    bit-identical and speculation keeps accepting on both sides."""
    cfg, model, params = build(layers=4)
    golds = [gold_decode(model, params, p, mn, 64)
             for p, mn, _ in SPEC_PROMPTS]
    mono, narrow, _ = _ladder(cfg.num_groups, slots=2)
    eng = run_staggered_replans(
        model, params, slots=2, sched=SPEC_PROMPTS,
        swaps=[(4, narrow), (8, mono)],
        speculate=2, paged=True, page_size=4)
    st = eng.stats()
    assert st["replans"] == 2
    assert st["spec_steps"] > 0 and st["spec_accepted"] > 0
    assert st["migration_copies"] == 0
    got = {r.uid: r.out_tokens for r in eng.done}
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"uid={uid}"


def test_replan_under_overlap_drains_inflight_first():
    """Overlap mode dispatches step N before draining step N-1; a
    re-plan must land the undrained step on the old binding first.
    Token streams across mono -> plan -> mono swaps stay gold."""
    cfg, model, params = build(layers=4)
    golds = [gold_decode(model, params, p, mn, 64) for p, mn, _ in STAGGERED]
    mono, narrow, _ = _ladder(cfg.num_groups, slots=2)
    eng = run_staggered_replans(
        model, params, slots=2,
        swaps=[(4, narrow), (9, mono)],
        overlap=True, paged=True, page_size=4)
    assert eng._overlap
    assert eng.stats()["replans"] == 2
    got = {r.uid: r.out_tokens for r in eng.done}
    for uid, gold in enumerate(golds):
        assert got[uid] == gold, f"uid={uid}"


def test_replan_to_unseen_plan_and_back_reuses_runtime_cache():
    """Runtime lowering is cached per ServingPlan value: swapping back to
    a previously-seen design point must reuse its compiled PlanRuntime
    (no recompilation storm while navigating the Pareto front)."""
    cfg, model, params = build(layers=4)
    mono, narrow, wide = _ladder(cfg.num_groups, slots=2)
    eng = ServingEngine(model, params, slots=2, max_seq=64, plan=narrow,
                        paged=True, page_size=4)
    rt0 = eng._rt
    eng.replan(wide)
    eng.replan(narrow)
    assert eng._rt is rt0
    eng.replan(mono)
    assert eng._rt is None and eng.plan is None
    assert eng.stats()["replans"] == 3
