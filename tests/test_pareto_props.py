"""Property tests for core/pareto.py on seeded-random point clouds
(including duplicates), and for the Table-6 query helper."""
import random

import pytest

from repro.core.pareto import DesignPoint, best_under_latency, pareto_front

STRATS = ("sequential", "spatial", "hybrid")


def cloud(seed, n=40, with_dups=True):
    rng = random.Random(seed)
    pts = [DesignPoint(strategy=rng.choice(STRATS),
                       n_acc=rng.randint(1, 8),
                       n_batches=rng.randint(1, 8),
                       latency=round(rng.uniform(1.0, 100.0), 1),
                       throughput_tops=round(rng.uniform(1.0, 100.0), 1))
           for _ in range(n)]
    if with_dups:  # exact duplicates and ties on one axis
        pts += [pts[i] for i in range(0, len(pts), 7)]
        p = pts[0]
        pts.append(DesignPoint("spatial", 2, 2, p.latency,
                               p.throughput_tops))
    return pts


def dominates(q, p):
    return ((q.latency <= p.latency
             and q.throughput_tops > p.throughput_tops)
            or (q.latency < p.latency
                and q.throughput_tops >= p.throughput_tops))


@pytest.mark.parametrize("seed", range(8))
def test_pareto_front_is_nondominated_and_latency_sorted(seed):
    pts = cloud(seed)
    front = pareto_front(pts)
    assert front, "a finite non-empty cloud always has a frontier"
    lats = [p.latency for p in front]
    assert lats == sorted(lats)
    for p in front:
        assert not any(dominates(q, p) for q in pts)


@pytest.mark.parametrize("seed", range(8))
def test_pareto_front_is_complete(seed):
    """Every point NOT on the front is dominated by some point of the
    front (so the front loses no achievable tradeoff)."""
    pts = cloud(seed)
    front = pareto_front(pts)
    front_ids = {id(p) for p in front}
    key = lambda p: (p.latency, p.throughput_tops)
    front_keys = {key(p) for p in front}
    for p in pts:
        if id(p) in front_ids or key(p) in front_keys:  # duplicate survivor
            continue
        assert any(dominates(q, p) for q in front), p


def test_pareto_front_keeps_duplicate_optima():
    """Two identical non-dominated points: neither dominates the other,
    both stay on the front (duplicates must not knock each other out)."""
    a = DesignPoint("sequential", 1, 1, 10.0, 50.0)
    b = DesignPoint("spatial", 4, 1, 10.0, 50.0)
    c = DesignPoint("hybrid", 2, 2, 20.0, 10.0)   # dominated
    front = pareto_front([a, b, c])
    assert a in front and b in front and c not in front


@pytest.mark.parametrize("seed", range(8))
def test_best_under_latency_respects_constraint(seed):
    rng = random.Random(seed + 100)
    pts = cloud(seed)
    for _ in range(10):
        cons = rng.uniform(0.0, 110.0)
        got = best_under_latency(pts, cons)
        feas = [p for p in pts if p.latency <= cons]
        if not feas:
            assert got is None
        else:
            assert got.latency <= cons
            assert got.throughput_tops == max(
                p.throughput_tops for p in feas)


@pytest.mark.parametrize("strategy", ["sequential", "spatial"])
def test_best_under_latency_strategy_filter(strategy):
    pts = cloud(3)
    cons = 60.0
    got = best_under_latency(pts, cons, strategy=strategy)
    feas = [p for p in pts
            if p.latency <= cons and p.strategy == strategy]
    if not feas:
        assert got is None
    else:
        assert got.strategy == strategy
        assert got.throughput_tops == max(p.throughput_tops for p in feas)


def test_best_under_latency_hybrid_includes_endpoint_designs():
    """Per the Table-6 note, the hybrid space includes the sequential and
    spatial endpoints: the hybrid query considers every strategy."""
    pts = cloud(4)
    cons = 60.0
    got = best_under_latency(pts, cons, strategy="hybrid")
    feas = [p for p in pts if p.latency <= cons]
    assert (got is None) == (not feas)
    if feas:
        assert got.throughput_tops == max(p.throughput_tops for p in feas)


def test_best_under_latency_infeasible_returns_none():
    pts = cloud(5)
    assert best_under_latency(pts, 0.0) is None
