"""ExecutionPlan subsystem: lowering invariants, uneven-stage round-trip
(1-device stage-chaining; the multi-device shard_map path is covered by
tests/test_distributed.py), and the measured-vs-predicted validate path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, ShapeConfig, reduced
from repro.core import build_graph, evolutionary_search, ssr_dse
from repro.core.assignment import Assignment, sequential_assignment
from repro.core.costmodel import AccConfig
from repro.models import build_model
from repro.plan import (ExecutionPlan, StagePlan, check_roundtrip, fit_dp_tp,
                        lower, measure_plan, measured_design_points,
                        predict_plan, realized_assignment, uniform_plan)
from repro.pipeline import plan_stage_params, stage_params_reshape


def _setup(layers=4, batch=8, seq=16):
    cfg = reduced(REGISTRY["yi-6b"], layers=layers)
    shape = ShapeConfig("t", seq, batch, "prefill")
    g = build_graph(cfg, shape)
    return cfg, shape, g


# ---------------------------------------------------------------------------
# IR invariants
# ---------------------------------------------------------------------------

def test_plan_stages_must_tile_group_axis():
    s0 = StagePlan(index=0, acc_id=0, first_group=0, n_groups=2)
    s1 = StagePlan(index=1, acc_id=1, first_group=2, n_groups=1)
    p = ExecutionPlan(stages=(s0, s1), num_groups=3, n_microbatches=2)
    assert p.max_groups == 2 and not p.is_uniform
    with pytest.raises(AssertionError):
        ExecutionPlan(stages=(s0, s0), num_groups=4, n_microbatches=1)


def test_group_matrices_clamped_and_masked():
    p = ExecutionPlan(
        stages=(StagePlan(0, 0, 0, 3), StagePlan(1, 1, 3, 1)),
        num_groups=4, n_microbatches=2)
    idx = p.group_index_matrix()
    msk = p.group_mask_matrix()
    assert idx.tolist() == [[0, 1, 2], [3, 3, 3]]
    assert msk.tolist() == [[1, 1, 1], [1, 0, 0]]
    # every real group appears exactly once under the mask
    live = idx[msk > 0]
    assert sorted(live.tolist()) == [0, 1, 2, 3]


def test_uniform_plan_matches_legacy_reshape():
    cfg, _, _ = _setup(layers=4)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    plan = uniform_plan(cfg.num_groups, 2, 2)
    a = plan_stage_params(params["stack"], plan)
    b = stage_params_reshape(params["stack"], 2)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert la.shape == lb.shape
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert float(plan.group_mask_matrix().min()) == 1.0


def test_fit_dp_tp():
    assert fit_dp_tp(4, 4, 1) == (4, 1)
    assert fit_dp_tp(4, 1, 4) == (1, 4)
    assert fit_dp_tp(4, 5, 1, max_dp=2) == (2, 2)
    dp, tp = fit_dp_tp(6, 3, 2)
    assert dp * tp == 6


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------

def test_lower_uneven_cuts_and_widths():
    cfg, _, g = _setup(layers=4)
    # layers 0-2 -> acc0, layer 3 -> acc1 (uneven); heterogeneous chips
    acc_of = (0, 0, 0, 0, 1, 1)
    _, _, assign = ssr_dse(g, acc_of, 8, n_batches=2)
    assert assign.accs[0].chips != assign.accs[1].chips
    plan = lower(assign, g, mesh_devices=8, n_microbatches=4)
    assert plan.n_stages == 2
    assert [s.n_groups for s in plan.stages] == [3, 1]
    assert all(s.width == 4 for s in plan.stages)       # uniform slot
    # the narrow acc is replicate-padded: waste recorded
    assert plan.stages[1].replica_waste > 0
    assert plan.stages[0].replica_waste == 0.0
    assert 0.0 < plan.padding_waste < 1.0


def test_lower_snaps_scattered_assignment_to_group_runs():
    cfg, _, g = _setup(layers=4)
    # EA mutation can scatter: acc pattern 0,1,0,1 over layers -> 4 stages
    acc_of = (0, 0, 1, 0, 1, 1)
    plan = lower(Assignment(acc_of, (AccConfig(4, 2, 2),
                                     AccConfig(4, 4, 1))), g, mesh_devices=8)
    assert sum(s.n_groups for s in plan.stages) == cfg.num_groups
    firsts = [s.first_group for s in plan.stages]
    assert firsts == sorted(firsts)
    assert plan.n_stages == 4        # two runs per acc


def test_lower_rejects_op_granularity_graphs():
    cfg = REGISTRY["yi-6b"]
    g = build_graph(cfg, ShapeConfig("t", 16, 8, "prefill"),
                    granularity="op")
    a = sequential_assignment(g, 8)
    with pytest.raises(ValueError):
        lower(a, g, mesh_devices=8)


def test_realized_assignment_covers_all_nodes():
    cfg, _, g = _setup(layers=4)
    _, _, assign = ssr_dse(g, (0, 0, 0, 0, 1, 1), 8, n_batches=2)
    plan = lower(assign, g, mesh_devices=8)
    ra = realized_assignment(plan, g)
    assert len(ra.acc_of) == len(g.nodes)
    assert ra.n_acc == plan.n_stages
    for s in plan.stages:
        assert s.width == ra.accs[s.index].chips


# ---------------------------------------------------------------------------
# round-trip: EA-searched plan executes identically to the reference
# ---------------------------------------------------------------------------

def test_roundtrip_uneven_ea_plan_matches_reference():
    cfg, _, g = _setup(layers=4)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, (8, 16)), jnp.int32)}
    # EA-searched assignment...
    res = evolutionary_search(g, 8, n_acc=3, n_batches=2, n_pop=6,
                              n_child=6, n_iter=3, seed=1)
    plan = lower(res.assignment, g, mesh_devices=8, n_microbatches=4)
    assert check_roundtrip(m, params, batch, plan) < 1e-4
    # ...and a guaranteed-uneven hybrid (EA may collapse to 1 stage)
    _, _, assign = ssr_dse(g, (0, 0, 0, 0, 1, 1), 8, n_batches=2)
    plan = lower(assign, g, mesh_devices=8, n_microbatches=4)
    assert not plan.is_uniform
    assert check_roundtrip(m, params, batch, plan) < 1e-4


def test_masked_padded_stage_equals_plain_slice():
    """The executor's padded+masked stage == the unpadded slice: dead
    groups must pass activations through unchanged (run_stack group_mask)."""
    from repro.models import transformer as T
    cfg, _, g = _setup(layers=4)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    _, _, assign = ssr_dse(g, (0, 0, 0, 0, 1, 1), 8, n_batches=2)
    plan = lower(assign, g, mesh_devices=8)
    staged = plan_stage_params(params["stack"], plan)
    mask = plan.group_mask_matrix()
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          cfg.dtype)
    for s in plan.stages:
        padded, _, _ = T.run_stack(
            jax.tree.map(lambda a, i=s.index: a[i], staged), x, cfg,
            group_mask=jnp.asarray(mask[s.index]))
        sl = jax.tree.map(
            lambda a, st=s: a[st.first_group:st.first_group + st.n_groups],
            params["stack"])
        plain, _, _ = T.run_stack(sl, x, cfg)
        err = float(jnp.max(jnp.abs(padded - plain)))
        assert err < 1e-5, (s.index, err)


def test_group_mask_requires_stateless_path():
    cfg, _, _ = _setup(layers=2)
    from repro.models import transformer as T
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    x = jnp.zeros((1, 4, cfg.d_model), cfg.dtype)
    cache = m.init_cache(1, 8)
    with pytest.raises(AssertionError):
        T.run_stack(params["stack"], x, cfg, cache=cache,
                    cache_index=jnp.int32(0), collect_state=True,
                    group_mask=jnp.ones((cfg.num_groups,)))


# ---------------------------------------------------------------------------
# validate: measured vs predicted
# ---------------------------------------------------------------------------

def test_measure_and_predict_plan():
    cfg, _, g = _setup(layers=4)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = {"tokens": jnp.ones((8, 16), jnp.int32)}
    _, _, assign = ssr_dse(g, (0, 0, 0, 0, 1, 1), 8, n_batches=2)
    plan = lower(assign, g, mesh_devices=8, n_microbatches=2)
    meas = measure_plan(m, params, batch, plan, repeat=1)
    assert meas["max_abs_err"] < 1e-4
    assert len(meas["per_stage_s"]) == 2
    assert all(t > 0 for t in meas["per_stage_s"])
    assert meas["makespan_s"] >= meas["latency_s"] > 0
    pred = predict_plan(plan, g)
    assert pred["makespan_s"] >= pred["latency_s"] > 0
    assert pred["throughput_tops"] > 0
    assert pred["padding_waste"] == plan.padding_waste


def test_measured_design_points_tagged():
    cfg, _, g = _setup(layers=4)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = {"tokens": jnp.ones((8, 16), jnp.int32)}
    _, _, assign = ssr_dse(g, (0, 0, 0, 0, 1, 1), 8, n_batches=2)
    plans = [lower(assign, g, mesh_devices=8, n_microbatches=2)]
    pts = measured_design_points(m, params, batch, g, plans, repeat=1)
    assert len(pts) == 1
    assert pts[0].source == "measured"
    assert pts[0].latency > 0 and pts[0].throughput_tops > 0
    # analytic points keep the default tag
    from repro.core.pareto import DesignPoint
    assert DesignPoint("s", 1, 1, 1.0, 1.0).source == "analytic"


def test_plan_mesh_factors():
    _, _, g = _setup(layers=4)
    _, _, assign = ssr_dse(g, (0, 0, 0, 0, 1, 1), 8, n_batches=2)
    plan = lower(assign, g, mesh_devices=8)
    data, model = plan.mesh_factors()
    assert data * model == plan.stage_width


# ---------------------------------------------------------------------------
# lower() edge cases + shim error reporting
# ---------------------------------------------------------------------------

def test_lower_single_group_graph():
    """A one-group model lowers to a single one-group stage regardless of
    how many accs the assignment scatters layers over."""
    cfg, _, g = _setup(layers=1)
    assert cfg.num_groups == 1
    _, _, assign = ssr_dse(g, (0,) * len(g.nodes), 4, n_batches=1)
    plan = lower(assign, g, mesh_devices=4)
    assert plan.n_stages == 1 and plan.num_groups == 1
    assert plan.stages[0].n_groups == 1
    assert plan.max_groups == 1 and plan.is_uniform


def test_lower_all_nodes_one_acc_collapses_to_sequential():
    cfg, _, g = _setup(layers=4)
    _, _, assign = ssr_dse(g, (0,) * len(g.nodes), 8, n_batches=2)
    plan = lower(assign, g, mesh_devices=8)
    assert plan.n_stages == 1
    assert plan.stages[0].n_groups == cfg.num_groups
    assert plan.stages[0].width == 8
    assert plan.padding_waste == 0.0


def test_lower_rejects_zero_rounds():
    cfg, _, g = _setup(layers=4)
    _, _, assign = ssr_dse(g, (0, 0, 0, 0, 1, 1), 8, n_batches=2)
    with pytest.raises(AssertionError):
        lower(assign, g, mesh_devices=8, n_microbatches=2, n_rounds=0)


def test_multi_round_plan_roundtrip_through_plan_forward():
    """n_rounds > 1 (the sequential dimension) streams M*n_rounds
    microbatches through plan_forward and still matches the reference
    forward — on the 1-device host mesh (single-stage plan)."""
    from repro.launch.mesh import make_plan_mesh
    from repro.pipeline import plan_forward
    cfg, _, g = _setup(layers=4)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, (8, 16)), jnp.int32)}
    _, _, assign = ssr_dse(g, (0,) * len(g.nodes), 8, n_batches=2)
    plan = lower(assign, g, mesh_devices=1, n_microbatches=2, n_rounds=2)
    assert plan.n_stages == 1 and plan.total_microbatches == 4
    mesh = make_plan_mesh(plan, devices=jax.devices()[:1])
    got = plan_forward(m, params, batch, mesh, plan)
    ref, _ = m.forward(params, batch)
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < 1e-4


def test_uniform_plan_rejects_non_dividing_stages():
    with pytest.raises(ValueError, match="does not evenly divide"):
        uniform_plan(4, 3, 2)
    with pytest.raises(ValueError, match="does not evenly divide"):
        uniform_plan(4, 0, 2)


def test_pipeline_mesh_rejects_non_dividing_stage_count():
    from repro.launch.mesh import make_pipeline_mesh
    with pytest.raises(ValueError, match="does not evenly divide"):
        make_pipeline_mesh(3, model=16, total=256)   # 3*16 !| 256


def test_plan_mesh_rejects_too_few_devices():
    from repro.launch.mesh import make_plan_mesh
    _, _, g = _setup(layers=4)
    _, _, assign = ssr_dse(g, (0, 0, 0, 0, 1, 1), 8, n_batches=2)
    plan = lower(assign, g, mesh_devices=8)
    with pytest.raises(ValueError, match="every stage needs"):
        make_plan_mesh(plan, devices=jax.devices()[:1])


# ---------------------------------------------------------------------------
# n_microbatches="auto" (plan-aware spatial-width tuning)
# ---------------------------------------------------------------------------

def test_lower_auto_microbatches_analytic():
    cfg, shape, g = _setup(layers=4)
    _, _, assign = ssr_dse(g, (0, 0, 0, 0, 1, 1), 8, n_batches=2)
    plan = lower(assign, g, mesh_devices=8, n_microbatches="auto")
    B = shape.global_batch
    assert 1 <= plan.n_microbatches <= B
    assert B % plan.n_microbatches == 0          # executor contract
    # the chosen width is the analytic-makespan argmin over the candidates
    from repro.plan import predict_plan
    chosen = predict_plan(plan, g)["makespan_s"]
    for m in (1, B):
        other = lower(assign, g, mesh_devices=8, n_microbatches=m)
        assert chosen <= predict_plan(other, g)["makespan_s"] + 1e-12


def test_lower_auto_microbatches_measured():
    cfg, shape, g = _setup(layers=4)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = {"tokens": jnp.ones((8, 16), jnp.int32)}
    _, _, assign = ssr_dse(g, (0, 0, 0, 0, 1, 1), 8, n_batches=2)
    plan = lower(assign, g, mesh_devices=8, n_microbatches="auto",
                 measure_with=(m, params, batch))
    assert 1 <= plan.n_microbatches <= 8
    assert 8 % plan.n_microbatches == 0
    # auto must respect n_rounds in the divisibility contract too
    plan2 = lower(assign, g, mesh_devices=8, n_microbatches="auto",
                  n_rounds=2)
    assert 8 % (plan2.n_microbatches * 2) == 0
    # ...and reject rounds that make the contract unsatisfiable
    with pytest.raises(ValueError, match="does not divide"):
        lower(assign, g, mesh_devices=8, n_microbatches="auto", n_rounds=3)


# ---------------------------------------------------------------------------
# ServingPlan lowering
# ---------------------------------------------------------------------------

def test_lower_serving_partitions_slots():
    from repro.plan import lower_serving
    plan = uniform_plan(4, 2, n_microbatches=2)
    sp = lower_serving(plan, slots=5, chunk=8)
    assert sp.n_replicas == 2 and sp.replica_slots == (3, 2)
    assert sp.replica_of_slot(0) == (0, 0)
    assert sp.replica_of_slot(2) == (0, 2)
    assert sp.replica_of_slot(3) == (1, 0)
    assert sp.replica_range(1) == (3, 5)
    assert "decode replicas" in sp.describe()


def test_lower_serving_rejects_too_few_slots_and_bad_chunk():
    from repro.plan import lower_serving
    plan = uniform_plan(4, 2, n_microbatches=4)
    with pytest.raises(ValueError, match="decode replicas"):
        lower_serving(plan, slots=3, chunk=8)
    with pytest.raises(ValueError, match="chunk"):
        lower_serving(uniform_plan(4, 2, 1), slots=2, chunk=0)


def test_place_params_single_device_passthrough():
    """Replica param sharing degrades gracefully: with fewer devices than
    stages (the 1-device CPU host) the params pass through unplaced."""
    from repro.plan.serving import place_params
    cfg, _, _ = _setup(layers=4)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    plan = uniform_plan(cfg.num_groups, 2, n_microbatches=2)
    placed, mesh = place_params(params, plan)
    assert mesh is None and placed is params
