"""Training substrate: AdamW math, schedules, grad accumulation."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduced
from repro.models import build_model
from repro.training import AdamW, make_train_step, zero1_specs


def test_adamw_decreases_quadratic():
    opt = AdamW(lr=0.1, warmup_steps=1, total_steps=300, weight_decay=0.0,
                clip_norm=100.0)
    p = {"w": jnp.array([5.0, -3.0])}
    st = opt.init(p)
    for _ in range(150):
        g = {"w": 2 * p["w"]}
        p, st, _ = opt.update(g, st, p)
    assert float(jnp.max(jnp.abs(p["w"]))) < 1.0


def test_schedule_warmup_and_decay():
    opt = AdamW(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(opt.schedule(jnp.int32(1))) < 0.2
    assert float(opt.schedule(jnp.int32(10))) == pytest.approx(1.0, abs=0.02)
    assert float(opt.schedule(jnp.int32(100))) == pytest.approx(0.1, abs=0.02)


def test_grad_clip_applied():
    opt = AdamW(lr=1e-3, clip_norm=1.0, warmup_steps=1, total_steps=10)
    p = {"w": jnp.zeros((4,))}
    st = opt.init(p)
    _, _, metrics = opt.update({"w": jnp.full((4,), 1e6)}, st, p)
    assert float(metrics["grad_norm"]) > 1.0   # raw norm reported


def test_grad_accum_equivalence():
    """grad_accum=2 must match grad_accum=1 on the same global batch
    (up to fp accumulation noise)."""
    cfg = reduced(REGISTRY["yi-6b"], layers=1)
    model = build_model(cfg)
    opt = AdamW(lr=1e-3, warmup_steps=1, total_steps=10)
    p = model.init(jax.random.key(0))
    st = opt.init(p)
    r = np.random.default_rng(0)
    B, S = 4, 16
    batch = {"tokens": jnp.asarray(r.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(r.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    s1 = jax.jit(make_train_step(model, opt, remat=False, grad_accum=1))
    s2 = jax.jit(make_train_step(model, opt, remat=False, grad_accum=2))
    p1, _, m1 = s1(p, st, batch)
    p2, _, m2 = s2(p, st, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert err < 1e-4, err       # fp32 accumulation-order noise


def test_remat_matches_no_remat():
    cfg = reduced(REGISTRY["yi-6b"], layers=1)
    model = build_model(cfg)
    opt = AdamW(lr=1e-3, warmup_steps=1, total_steps=10)
    p = model.init(jax.random.key(0))
    st = opt.init(p)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    pa, _, ma = jax.jit(make_train_step(model, opt, remat=False))(p, st, batch)
    pb, _, mb = jax.jit(make_train_step(model, opt, remat=True))(p, st, batch)
    assert abs(float(ma["loss"]) - float(mb["loss"])) < 1e-6
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)))
    assert err < 1e-5


def test_zero1_specs_add_data_axis():
    from jax.sharding import PartitionSpec as P

    from repro.backend.compat import make_abstract_mesh
    mesh = make_abstract_mesh((4, 2), ("data", "model"))
    params = {"w": jax.ShapeDtypeStruct((8, 16), np.float32)}
    specs = {"w": P(None, "model")}
    z = zero1_specs(specs, params, mesh)
    assert z["w"] == P("data", "model")
