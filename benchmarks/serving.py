"""Serving benchmark: measured latency-throughput tradeoff under Poisson
arrivals, at several slot counts, on the per-slot continuous-batching
engine — and across *ServingPlans* (plan-driven engines).

Each slot count is one *serving design point*: more slots = fuller decode
batches = higher throughput, but deeper queues = higher per-request
latency — the serving-side analogue of the paper's batch sweeps.
``serving_design_points`` returns the sweep as ``core.pareto.DesignPoint``
rows (strategy ``serving-<n>slots``) so measured serving points sit on the
same Pareto axes as the analytical design points from ``core/pareto.py``
(latency in seconds, throughput field carrying generated tok/s).

``plan_serving_sweep`` runs the SAME Poisson trace through plan-driven
engines — sequential (one stage, one decode replica), spatial (one stage
per group, max decode replicas), and an uneven DSE-searched hybrid plan —
and ``served_design_points`` tags them ``source="served"``: the paper's
strategy tradeoff measured under live request traffic rather than
synthetic pipelined forwards.

``paged_serving_sweep`` compares dense vs paged (block-pool) cache
layouts over one request set with shared-prefix prompts: token parity is
asserted between the layouts, and the rows report block-pool occupancy,
prefix-reuse hit rate, copy-on-write counts, and the effective-slots
gain (``paged_design_points``, also ``source="served"``).

``prefix_reuse_sweep`` measures prefix COMPUTE reuse: requests sharing a
long warm prefix admit suffix-only (the registry supplies the prefix
K/V), so their TTFT drops below cold same-length requests — the sweep
reports cold vs warm TTFT medians, prefill hit rate, and block/token
savings.

``adaptive_sweep`` replays ONE time-varying trace (calm -> spike ->
long-prompt burst) through every static candidate engine AND through an
adaptive engine carrying the same candidates (``AdaptiveConfig``): token
parity across all legs is asserted (re-planning is scheduling-only), the
adaptive leg's paged migrations must be zero-copy, and CI gates its
throughput / p50 TTFT against the best static leg.
``serving_bench_summary`` packages everything as the
``BENCH_serving.json`` payload the smoke run archives.

    PYTHONPATH=src python benchmarks/run.py serving
    python benchmarks/run.py serving --smoke   # small plan + paged-vs-dense
"""
from __future__ import annotations

import time
from typing import List, Sequence, Tuple

import numpy as np


def _drive_poisson(eng, cfg, requests: int, new_tokens: int,
                   rate_rps: float, seed: int):
    """Submit `requests` Poisson arrivals (exponential gaps at rate_rps)
    against the wall clock while ticking the engine."""
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=requests)
    arrivals = np.cumsum(gaps)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(3, 12))).astype(np.int32)
               for _ in range(requests)]
    t0 = time.perf_counter()
    nxt = 0
    busy = True
    while busy or nxt < requests:
        now = time.perf_counter() - t0
        while nxt < requests and arrivals[nxt] <= now:
            eng.submit(Request(nxt, prompts[nxt], new_tokens))
            nxt += 1
        busy = eng.tick()
        if not busy and nxt < requests:
            wait = arrivals[nxt] - (time.perf_counter() - t0)
            time.sleep(min(max(wait, 0.0), 0.01))
    return time.perf_counter() - t0


def serving_sweep(arch: str = "yi-6b", *,
                  slot_counts: Sequence[int] = (1, 2, 4),
                  requests: int = 10, new_tokens: int = 8,
                  rate_rps: float = 20.0, max_seq: int = 64,
                  seed: int = 0) -> List[dict]:
    """One engine per slot count over the same Poisson trace; returns a
    stats dict (engine stats + measured wall/percentiles) per point."""
    import jax

    from repro.configs import REGISTRY, reduced
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = reduced(REGISTRY[arch], layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    out = []
    for slots in slot_counts:
        eng = ServingEngine(model, params, slots=slots, max_seq=max_seq)
        # warmup: compile prefill/decode outside the measured window
        eng.submit(Request(-1, np.arange(1, 6, dtype=np.int32), 2))
        eng.run()
        eng.reset_stats()
        wall = _drive_poisson(eng, cfg, requests, new_tokens, rate_rps, seed)
        st = eng.stats()
        st.update(slots=slots, wall_s=wall, arch=arch,
                  lat_p50_s=float(np.percentile(st["latency_s"], 50)),
                  lat_p95_s=float(np.percentile(st["latency_s"], 95)),
                  ttft_p50_s=float(np.percentile(st["ttft_s"], 50)))
        out.append(st)
    return out


def serving_design_points(stats: Sequence[dict]):
    """Map measured serving points onto the analytical Pareto axes."""
    from repro.core.pareto import DesignPoint

    return [DesignPoint(strategy=f"serving-{s['slots']}slots", n_acc=1,
                        n_batches=s["slots"], latency=s["lat_p50_s"],
                        throughput_tops=s["throughput_tok_s"],
                        detail=f"occ={s['slot_occupancy']:.2f}")
            for s in stats]


def _shared_prefix_prompts(rng, cfg, requests: int, prefix_len: int = 8):
    """Half the trace shares one prompt prefix (>= one block at the bench
    page sizes) so the paged sweep exercises prefix reuse; the other half
    is independent."""
    prefix = rng.integers(1, cfg.vocab_size, size=prefix_len)
    out = []
    for i in range(requests):
        tail = rng.integers(1, cfg.vocab_size, size=int(rng.integers(2, 6)))
        if i % 2 == 0:
            out.append(np.concatenate([prefix, tail]).astype(np.int32))
        else:
            out.append(rng.integers(1, cfg.vocab_size,
                                    size=int(rng.integers(3, 12))
                                    ).astype(np.int32))
    return out


def _drive_submissions(eng, prompts, new_tokens: int):
    """Deterministic drive: submit everything, run to completion (the
    paged-vs-dense comparison wants identical request sets, and the
    parity guarantee makes arrival times irrelevant to the streams)."""
    from repro.serving import Request

    t0 = time.perf_counter()
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, new_tokens))
    eng.run()
    return time.perf_counter() - t0


def paged_serving_sweep(arch: str = "yi-6b", *, slots: int = 4,
                        requests: int = 10, new_tokens: int = 8,
                        max_seq: int = 64, page_sizes: Sequence[int] = (4, 8),
                        seed: int = 0) -> List[dict]:
    """Dense vs paged engines over one request set with shared-prefix
    prompts: asserts token parity between the layouts, measures the block
    pool (occupancy, prefix-reuse hit rate, copy-on-write) and the
    effective-slots gain of paging.  One stats dict per cache layout."""
    import jax

    from repro.configs import REGISTRY, reduced
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = reduced(REGISTRY[arch], layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(seed)
    prompts = _shared_prefix_prompts(rng, cfg, requests)

    out = []
    gold_streams = None
    variants = [("dense", {})] + [
        (f"paged-p{p}", {"paged": True, "page_size": p}) for p in page_sizes]
    for name, kw in variants:
        eng = ServingEngine(model, params, slots=slots, max_seq=max_seq,
                            **kw)
        # warmup: compile prefill/decode outside the measured window
        eng.submit(Request(-1, np.arange(1, 6, dtype=np.int32), 2))
        eng.run()
        eng.reset_stats()
        wall = _drive_submissions(eng, prompts, new_tokens)
        streams = {r.uid: list(r.out_tokens) for r in eng.done}
        if gold_streams is None:
            gold_streams = streams
        else:
            assert streams == gold_streams, (
                f"paged engine {name} diverged from dense token streams")
        st = eng.stats()
        st.update(layout=name, slots=slots, wall_s=wall, arch=arch,
                  lat_p50_s=float(np.percentile(st["latency_s"], 50)),
                  lat_p95_s=float(np.percentile(st["latency_s"], 95)),
                  ttft_p50_s=float(np.percentile(st["ttft_s"], 50)))
        out.append(st)
    return out


def paged_design_points(stats: Sequence[dict]):
    """Paged-vs-dense measurements on the shared Pareto axes, tagged
    ``source="served"`` — the detail string carries the block-pool story
    (occupancy, reuse-hit rate, effective-slots gain)."""
    from repro.core.pareto import DesignPoint

    pts = []
    for s in stats:
        c = s["cache"]
        if c["layout"] == "paged":
            detail = (f"blocks={c['peak_blocks_in_use']}/{c['num_blocks']} "
                      f"reuse={c['reuse_hit_rate']:.2f} "
                      f"cow={c['cow_copies']} "
                      f"eff_slots_gain={c['effective_slots_gain']:.1f}x")
        else:
            detail = (f"reserved_tokens={c['reserved_tokens']} "
                      f"util={c['utilization']:.2f}")
        pts.append(DesignPoint(
            strategy=f"{s['layout']}-{s['slots']}slots", n_acc=1,
            n_batches=s["slots"], latency=s["lat_p50_s"],
            throughput_tops=s["throughput_tok_s"], detail=detail,
            source="served"))
    return pts


def _paged_rows(pstats: Sequence[dict]) -> List[Tuple[str, float, str]]:
    out = []
    for s, p in zip(pstats, paged_design_points(pstats)):
        name = f"serving/paged/{s['arch']}/{s['layout']}-{s['slots']}slots"
        out.append((name, s["lat_p50_s"] * 1e6,
                    f"source={p.source} "
                    f"tok_s={s['throughput_tok_s']:.1f} "
                    f"parity=Y {p.detail}"))
    return out


def prefix_reuse_sweep(arch: str = "yi-6b", *, slots: int = 2,
                       requests: int = 6, prefix_len: int = 120,
                       tail_len: int = 4, new_tokens: int = 4,
                       max_seq: int = 160, page_size: int = 4,
                       seed: int = 0) -> dict:
    """Cold vs warm TTFT under shared-prefix traffic on one paged engine.

    The warm leg admits prompts sharing one registered ``prefix_len``
    prefix, so each prefills only its ``tail_len`` suffix; the cold leg
    admits prompts with DISTINCT prefixes (same length, so both legs
    prefill at the same padded shape family).  Requests run one at a
    time (clean TTFT); both prefill shapes are compiled during warmup,
    so the medians compare pure suffix-vs-full prefill work.  The warm
    leg runs FIRST — the cold leg's parked blocks eventually crowd the
    shared prefix out of the LRU, which must not poison the warm
    measurements."""
    import jax

    from repro.configs import REGISTRY, reduced
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = reduced(REGISTRY[arch], layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(seed)
    eng = ServingEngine(model, params, slots=slots, max_seq=max_seq,
                        paged=True, page_size=page_size, prefill_bucket=4)

    shared = rng.integers(1, cfg.vocab_size, size=prefix_len)

    def prompt(warm: bool):
        head = shared if warm else rng.integers(1, cfg.vocab_size,
                                                size=prefix_len)
        tail = rng.integers(1, cfg.vocab_size, size=tail_len)
        return np.concatenate([head, tail]).astype(np.int32)

    def one(p, uid: int) -> float:
        eng.submit(Request(uid, p, new_tokens))
        eng.run()
        r = eng.done[-1]
        return r.t_first - r.t_submit

    # warmup: compile the full-prompt shape AND the suffix-only shape
    # outside the measured window; the first warm prompt also registers
    # the shared prefix (it is itself a cold admission).
    one(prompt(False), -3)
    one(prompt(True), -2)                     # registers the shared prefix
    one(prompt(True), -1)                     # compiles the suffix shape
    eng.reset_stats()                         # registry survives the reset

    warm = [one(prompt(True), 200 + i) for i in range(requests)]
    cold = [one(prompt(False), 100 + i) for i in range(requests)]
    st = eng.stats()
    c = st["cache"]
    total_tokens = c["reused_prefill_tokens"] + c["suffix_prefill_tokens"]
    return {
        "arch": arch, "page_size": page_size, "prefix_len": prefix_len,
        "tail_len": tail_len, "requests_per_leg": requests,
        "throughput_tok_s": st["throughput_tok_s"],
        "ttft_cold_p50_s": float(np.median(cold)),
        "ttft_warm_p50_s": float(np.median(warm)),
        "ttft_speedup": float(np.median(cold)
                              / max(float(np.median(warm)), 1e-9)),
        "prefill_hit_rate": c["prefill_hit_rate"],
        "reused_prefill_tokens": c["reused_prefill_tokens"],
        "suffix_prefill_tokens": c["suffix_prefill_tokens"],
        "token_savings_frac": (c["reused_prefill_tokens"]
                               / max(total_tokens, 1)),
        "blocks_saved": c["prefix_hits"],     # registry blocks not written
        "phase_time_s": st["phase_time_s"],
    }


def _prefix_rows(s: dict) -> List[Tuple[str, float, str]]:
    name = (f"serving/prefix-reuse/{s['arch']}/"
            f"prefix{s['prefix_len']}-p{s['page_size']}")
    return [(name, s["ttft_warm_p50_s"] * 1e6,
             f"ttft_cold_ms={s['ttft_cold_p50_s']*1e3:.1f} "
             f"ttft_warm_ms={s['ttft_warm_p50_s']*1e3:.1f} "
             f"speedup={s['ttft_speedup']:.2f}x "
             f"hit_rate={s['prefill_hit_rate']:.2f} "
             f"tok_saved={s['token_savings_frac']:.2f} "
             f"blocks_saved={s['blocks_saved']}")]


def _repetitive_prompts(rng, cfg, requests: int, period: int = 2,
                        repeats: int = 4):
    """Each prompt tiles a short random pattern, so the prompt-lookup
    drafter has an n-gram match to propose from on the first tick."""
    out = []
    for _ in range(requests):
        pat = rng.integers(1, cfg.vocab_size, size=period)
        out.append(np.tile(pat, repeats).astype(np.int32))
    return out


def speculative_sweep(arch: str = "yi-6b", *, slots: int = 2,
                      requests: int = 6, new_tokens: int = 20,
                      speculate: int = 4, max_seq: int = 96,
                      page_size: int = 4, seed: int = 0) -> dict:
    """Spec-on vs spec-off over one repetitive-prompt request set on paged
    engines.  Token parity between the legs is ASSERTED (greedy verify
    makes speculation a pure latency optimisation); the payload reports
    the acceptance rate and decode tokens-per-step of each leg — the
    spec-on leg must clear 1.0 tokens/step (the CI gate) since every
    accepted draft token rides an existing verify step for free."""
    import jax

    from repro.configs import REGISTRY, reduced
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = reduced(REGISTRY[arch], layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(seed)
    prompts = _repetitive_prompts(rng, cfg, requests)

    legs = {}
    streams = {}
    for name, k in (("off", 0), ("on", speculate)):
        eng = ServingEngine(model, params, slots=slots, max_seq=max_seq,
                            paged=True, page_size=page_size, speculate=k)
        # warmup: compile prefill + decode (and the verify window shape)
        eng.submit(Request(-1, np.tile([3, 4], 4).astype(np.int32), 4))
        eng.run()
        eng.reset_stats()
        wall = _drive_submissions(eng, prompts, new_tokens)
        streams[name] = {r.uid: list(r.out_tokens) for r in eng.done}
        st = eng.stats()
        legs[name] = {"wall_s": wall, "decode_steps": st["decode_steps"],
                      "tokens_per_step": st["tokens_per_step"],
                      "acceptance_rate": st["acceptance_rate"],
                      "throughput_tok_s": st["throughput_tok_s"]}
    assert streams["on"] == streams["off"], (
        "speculative decode diverged from plain greedy token streams")
    return {
        "arch": arch, "speculate": speculate, "slots": slots,
        "requests": requests, "new_tokens": new_tokens,
        "page_size": page_size, "parity": True,
        "tokens_per_step_on": legs["on"]["tokens_per_step"],
        "tokens_per_step_off": legs["off"]["tokens_per_step"],
        "acceptance_rate": legs["on"]["acceptance_rate"],
        "decode_steps_on": legs["on"]["decode_steps"],
        "decode_steps_off": legs["off"]["decode_steps"],
        "step_reduction": (1.0 - legs["on"]["decode_steps"]
                           / max(legs["off"]["decode_steps"], 1)),
        "wall_on_s": legs["on"]["wall_s"],
        "wall_off_s": legs["off"]["wall_s"],
    }


def _spec_rows(s: dict) -> List[Tuple[str, float, str]]:
    name = (f"serving/speculative/{s['arch']}/"
            f"k{s['speculate']}-p{s['page_size']}")
    return [(name, s["wall_on_s"] * 1e6,
             f"parity=Y tok_per_step={s['tokens_per_step_on']:.2f} "
             f"accept={s['acceptance_rate']:.2f} "
             f"steps={s['decode_steps_on']}/{s['decode_steps_off']} "
             f"step_reduction={s['step_reduction']:.2f}")]


def overlap_sweep(arch: str = "yi-6b", *, slots: int = 2, requests: int = 2,
                  new_tokens: int = 100, max_seq: int = 128,
                  page_size: int = 4, repeats: int = 5,
                  seed: int = 0) -> dict:
    """Sync vs async (overlapped dispatch/drain) legs over one fixed
    decode-heavy request set on paged engines.  Token parity between the
    legs is ASSERTED (the delayed drain re-times the host readback, never
    the streams); the payload reports each leg's throughput plus its
    ``host_sync`` share of the phase clock — the host time the async leg
    takes off the critical path.  CI gates ``async_speedup > 1`` — the
    median of paired per-round sync/async wall ratios.

    Defaults keep ``requests == slots``: with a queue, the async leg's
    one-tick-late retirement delays the next admission, which measures
    scheduling churn rather than the dispatch/drain overlap itself (and
    drowns the win in re-admission noise on CPU CI)."""
    import jax

    from repro.configs import REGISTRY, reduced
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = reduced(REGISTRY[arch], layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(seed)
    # short prompts, long generations: the decode loop dominates, which
    # is exactly where dispatch/drain overlap pays
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(3, 8))).astype(np.int32)
               for _ in range(requests)]

    def run_leg(name, ov):
        eng = ServingEngine(model, params, slots=slots, max_seq=max_seq,
                            paged=True, page_size=page_size, overlap=ov)
        # warmup: compile prefill + decode outside the measured window
        eng.submit(Request(-1, np.arange(1, 6, dtype=np.int32), 2))
        eng.run()
        # best-of-2 drives per leg per round: a single ~0.1 s drive is
        # exposed to scheduler hiccups bigger than the effect under test
        best = None
        for _ in range(2):
            eng.reset_stats()
            wall = _drive_submissions(eng, prompts, new_tokens)
            if best is None or wall < best[0]:
                best = (wall, {r.uid: list(r.out_tokens)
                               for r in eng.done}, eng.stats())
        wall, toks, st = best
        streams[name] = toks
        pt = st["phase_time_s"]
        return {"wall_s": wall,
                "throughput_tok_s": st["gen_tokens"] / wall,
                "decode_s": pt["decode"],
                "host_sync_s": pt["host_sync"],
                "host_sync_frac": pt["host_sync"] / max(
                    sum(v for k, v in pt.items() if k != "host_sync"),
                    1e-9)}

    legs = {}
    streams = {}
    ratios = []
    # PAIRED rounds: each round runs sync then async back-to-back and
    # contributes one wall ratio; the median ratio cancels machine-load
    # drift on a shared CI runner far better than comparing two
    # independently-noisy best-of walls.  Per-leg payloads keep the best
    # wall across rounds.  Collect garbage up front so an in-process run
    # after other sweeps doesn't eat collection pauses mid-drive.
    import gc
    gc.collect()
    for _ in range(max(repeats, 1)):
        round_walls = {}
        for name, ov in (("sync", False), ("async", True)):
            leg = run_leg(name, ov)
            round_walls[name] = leg["wall_s"]
            if name not in legs or leg["wall_s"] < legs[name]["wall_s"]:
                legs[name] = leg
        ratios.append(round_walls["sync"] / max(round_walls["async"], 1e-9))
    speedup = float(np.median(ratios))
    assert streams["async"] == streams["sync"], (
        "overlapped runtime diverged from sync token streams")
    return {
        "arch": arch, "slots": slots, "requests": requests,
        "new_tokens": new_tokens, "page_size": page_size, "parity": True,
        "throughput_sync_tok_s": legs["sync"]["throughput_tok_s"],
        "throughput_async_tok_s": legs["async"]["throughput_tok_s"],
        "async_speedup": speedup,
        "host_sync_sync_s": legs["sync"]["host_sync_s"],
        "host_sync_async_s": legs["async"]["host_sync_s"],
        "host_sync_frac_sync": legs["sync"]["host_sync_frac"],
        "host_sync_frac_async": legs["async"]["host_sync_frac"],
        "wall_sync_s": legs["sync"]["wall_s"],
        "wall_async_s": legs["async"]["wall_s"],
    }


def _overlap_rows(s: dict) -> List[Tuple[str, float, str]]:
    name = f"serving/overlap/{s['arch']}/slots{s['slots']}-p{s['page_size']}"
    return [(name, s["wall_async_s"] * 1e6,
             f"parity=Y tok_s_async={s['throughput_async_tok_s']:.1f} "
             f"tok_s_sync={s['throughput_sync_tok_s']:.1f} "
             f"speedup={s['async_speedup']:.2f}x "
             f"host_sync_frac={s['host_sync_frac_async']:.2f}/"
             f"{s['host_sync_frac_sync']:.2f}")]


def int8_kv_sweep(arch: str = "yi-6b", *, slots: int = 2, requests: int = 6,
                  new_tokens: int = 8, max_seq: int = 64,
                  page_size: int = 4, seed: int = 0) -> dict:
    """fp vs int8 block pools over one request set: reports the
    effective-capacity multiplier from ``stats()["cache"]`` (int8 payload
    + per-row f32 scale vs the fp row — CI gates ``>= 1.9``) and each
    leg's throughput.  int8 legs complete every budget; token identity is
    bounded-error, not bit-exact (see tests/test_kernels.py)."""
    import jax

    from repro.configs import REGISTRY, reduced
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = reduced(REGISTRY[arch], layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(3, 10))).astype(np.int32)
               for _ in range(requests)]

    legs = {}
    for name in ("fp", "int8"):
        eng = ServingEngine(model, params, slots=slots, max_seq=max_seq,
                            paged=True, page_size=page_size, kv_dtype=name)
        eng.submit(Request(-1, np.arange(1, 6, dtype=np.int32), 2))
        eng.run()
        eng.reset_stats()
        wall = _drive_submissions(eng, prompts, new_tokens)
        st = eng.stats()
        assert all(len(r.out_tokens) == new_tokens for r in eng.done)
        legs[name] = {"wall_s": wall,
                      "throughput_tok_s": st["gen_tokens"] / wall,
                      "kv_capacity_x": st["cache"]["kv_capacity_x"],
                      "kv_dtype": st["cache"]["kv_dtype"]}
    return {
        "arch": arch, "slots": slots, "requests": requests,
        "new_tokens": new_tokens, "page_size": page_size,
        "kv_capacity_x": legs["int8"]["kv_capacity_x"],
        "throughput_fp_tok_s": legs["fp"]["throughput_tok_s"],
        "throughput_int8_tok_s": legs["int8"]["throughput_tok_s"],
        "wall_fp_s": legs["fp"]["wall_s"],
        "wall_int8_s": legs["int8"]["wall_s"],
    }


def _int8_rows(s: dict) -> List[Tuple[str, float, str]]:
    name = f"serving/int8-kv/{s['arch']}/slots{s['slots']}-p{s['page_size']}"
    return [(name, s["wall_int8_s"] * 1e6,
             f"kv_capacity_x={s['kv_capacity_x']:.2f} "
             f"tok_s_int8={s['throughput_int8_tok_s']:.1f} "
             f"tok_s_fp={s['throughput_fp_tok_s']:.1f}")]


def _adaptive_candidates(cfg, slots: int, chunk: int):
    """The controller's candidate ladder: monolithic (``None``) plus one
    searched stage cut at two spatial decode widths (narrow and wide),
    built via ``rereplicate_serving`` — the stage slices are shared, only
    the traffic-dependent replica knob differs."""
    from repro.plan import lower_serving, rereplicate_serving, uniform_plan

    G = cfg.num_groups
    stages = 2 if G % 2 == 0 else 1
    narrow = lower_serving(uniform_plan(G, stages, n_microbatches=1),
                           slots=slots, chunk=chunk)
    cands = [None, narrow]
    if slots > 1:
        cands.append(rereplicate_serving(narrow, slots))
    return cands


def _adaptive_trace(rng, cfg, *, phases=None):
    """A deterministic time-varying trace: calm low-rate short prompts,
    then a high-rate spike, then a long-prompt burst — the regime changes
    the controller is supposed to navigate.  Returns (arrivals, prompts);
    every leg replays the identical trace."""
    phases = phases or [(3, 4.0, 3, 8),      # calm: sparse short prompts
                        (14, 150.0, 3, 8),   # spike: same prompts, ~40x rate
                        (5, 15.0, 20, 28)]   # burst: long prompts
    arrivals, prompts, t = [], [], 0.0
    for n, rate_rps, lo, hi in phases:
        for _ in range(n):
            t += rng.exponential(1.0 / rate_rps)
            arrivals.append(t)
            prompts.append(rng.integers(1, cfg.vocab_size,
                                        size=int(rng.integers(lo, hi))
                                        ).astype(np.int32))
    return np.asarray(arrivals), prompts


def _drive_trace(eng, arrivals, prompts, new_tokens: int) -> float:
    """Replay a precomputed (arrivals, prompts) trace against the wall
    clock while ticking the engine (same loop as ``_drive_poisson`` but
    with the trace fixed, so every leg sees identical traffic)."""
    from repro.serving import Request

    n = len(prompts)
    t0 = time.perf_counter()
    nxt = 0
    busy = True
    while busy or nxt < n:
        now = time.perf_counter() - t0
        while nxt < n and arrivals[nxt] <= now:
            eng.submit(Request(nxt, prompts[nxt], new_tokens))
            nxt += 1
        busy = eng.tick()
        if not busy and nxt < n:
            wait = arrivals[nxt] - (time.perf_counter() - t0)
            time.sleep(min(max(wait, 0.0), 0.01))
    return time.perf_counter() - t0


def adaptive_sweep(arch: str = "yi-6b", *, layers: int = 4, slots: int = 4,
                   chunk: int = 4, new_tokens: int = 10, max_seq: int = 96,
                   page_size: int = 4, rounds: int = 2, seed: int = 0,
                   trace_out: str = None, metrics_out: str = None) -> dict:
    """Adaptive re-planning vs every static candidate on one time-varying
    trace (calm -> spike -> long-prompt burst), all on paged engines.

    One leg per static candidate (mono / narrow plan / wide plan) plus
    the adaptive leg (same candidates handed to the controller, warmed
    via ``warm_replans()`` so candidate compiles stay off the clock).
    Token parity across ALL legs is ASSERTED — re-planning is a pure
    scheduling optimisation — and the adaptive leg's paged migrations
    must be zero-copy (``migration_copies == 0``: block-table handoffs in
    the shared global pool, never KV copies).  Each leg keeps its best
    wall over ``rounds`` drives (CPU-CI noise).  CI gates the adaptive
    leg's throughput and p50 TTFT against the best static leg.

    ``trace_out`` / ``metrics_out`` add ONE extra traced replay of the
    same trace on the adaptive engine AFTER the measured legs (tracing
    stays off for every measured number): the engine is first re-planned
    onto the widest candidate so the calm opening phase forces the
    controller to swap back — guaranteeing the artifact carries per-stage
    ``prefill_chunk`` spans, per-replica decode tracks, and at least one
    replan event — then the Perfetto JSON / Prometheus text files are
    written."""
    import jax

    from repro.configs import REGISTRY, reduced
    from repro.models import build_model
    from repro.serving import AdaptiveConfig, Request, ServingEngine

    cfg = reduced(REGISTRY[arch], layers=layers)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(seed)
    arrivals, prompts = _adaptive_trace(rng, cfg)
    cands = _adaptive_candidates(cfg, slots, chunk)

    def leg_stats(eng):
        # best wall AND best TTFT-median over rounds, independently: both
        # are ~ms quantities on this trace, so a single round's scheduler
        # hiccup would otherwise dominate the cross-leg ratios CI gates
        best, ttfts = None, []
        for _ in range(max(rounds, 1)):
            eng.reset_stats()
            wall = _drive_trace(eng, arrivals, prompts, new_tokens)
            st = eng.stats()
            ttfts.append(float(np.percentile(st["ttft_s"], 50)))
            if best is None or wall < best[0]:
                st["wall_s"] = wall
                best = (wall, st,
                        {r.uid: list(r.out_tokens)
                         for r in eng.done if r.uid >= 0})
        _, st, toks = best
        return ({"wall_s": st["wall_s"],
                 "throughput_tok_s": st["gen_tokens"] / st["wall_s"],
                 "ttft_p50_s": min(ttfts),
                 "lat_p50_s": float(np.percentile(st["latency_s"], 50))},
                toks, st)

    legs = {}
    streams = {}
    for cand in cands:
        label = cand.label if cand is not None else "mono"
        eng = ServingEngine(model, params, slots=slots, max_seq=max_seq,
                            paged=True, page_size=page_size, plan=cand)
        # warmup: compile the prefill/decode (or stage) walks off the clock
        eng.submit(Request(-1, np.arange(1, 6, dtype=np.int32), 2))
        eng.run()
        legs[label], streams[label], _ = leg_stats(eng)

    # responsive controller: short decision interval / cooldown so the
    # spike and the burst are each long enough (in ticks) to react to;
    # generous SLOs keep the cost model from trading TTFT for throughput
    # (the same tradeoff the CI gate checks)
    eng = ServingEngine(model, params, slots=slots, max_seq=max_seq,
                        paged=True, page_size=page_size,
                        adapt=AdaptiveConfig(plans=cands, interval_ticks=4,
                                             cooldown_ticks=8, window_s=1.0,
                                             slo_ttft_s=0.05,
                                             slo_tpot_s=0.02))
    eng.warm_replans()               # compile every candidate off the clock
    adaptive, streams["adaptive"], ast = leg_stats(eng)
    adaptive.update(replans=ast["replans"], migrations=ast["migrations"],
                    migration_copies=ast["migration_copies"],
                    final_plan=ast["plan_label"],
                    decisions=[list(d) for d in eng._ctl.decisions])

    gold_label = next(iter(streams))
    gold = streams[gold_label]
    for name, toks in streams.items():
        assert toks == gold, (
            f"adaptive-sweep leg {name} diverged from {gold_label} "
            f"token streams — re-planning must be scheduling-only")

    trace_info = None
    if trace_out or metrics_out:
        tr = eng.enable_trace()
        for _ in range(2):                   # retry once if no live replan
            tr.clear()
            # start wide: the calm opening phase makes the controller's
            # first scoring tick want a narrower point, so the trace is
            # guaranteed a controller-driven replan (the forced swap below
            # lands in the trace too, but stats() counts only live ones)
            if len(cands) > 1 and eng.plan is not cands[-1]:
                eng.replan(cands[-1])
            eng.reset_stats()
            # pinned-wide burst with the controller detached: the live
            # controller narrows within ticks of the calm opening, so
            # without this the artifact could carry zero per-stage
            # ``prefill_chunk`` spans / stage-occupancy samples
            ctl, eng._ctl = eng._ctl, None
            for i, p in enumerate(prompts[:2]):
                eng.submit(Request(-10 - i, p, new_tokens))
            eng.run()
            eng._ctl = ctl
            _drive_trace(eng, arrivals, prompts, new_tokens)
            if eng.stats()["replans"] >= 1:
                break
        if trace_out:
            eng.write_trace(trace_out)
        if metrics_out:
            from repro.obs import write_metrics
            write_metrics(eng.export_metrics(), metrics_out)
        trace_info = {"trace_out": trace_out, "metrics_out": metrics_out,
                      "events": eng._tr.events, "dropped": eng._tr.dropped,
                      "traced_replans": eng.stats()["replans"]}

    best_label = max(legs, key=lambda k: legs[k]["throughput_tok_s"])
    best = legs[best_label]
    return {
        "arch": arch, "slots": slots, "chunk": chunk,
        "requests": len(prompts), "new_tokens": new_tokens,
        "page_size": page_size, "parity": True,
        "zero_copy": adaptive["migration_copies"] == 0,
        "legs": legs, "adaptive": adaptive,
        "best_static": best_label,
        "tok_s_ratio": (adaptive["throughput_tok_s"]
                        / max(best["throughput_tok_s"], 1e-9)),
        "ttft_ratio": (adaptive["ttft_p50_s"]
                       / max(best["ttft_p50_s"], 1e-9)),
        "trace": trace_info,
    }


def _adaptive_rows(s: dict) -> List[Tuple[str, float, str]]:
    a = s["adaptive"]
    name = f"serving/adaptive/{s['arch']}/slots{s['slots']}-c{s['chunk']}"
    return [(name, a["wall_s"] * 1e6,
             f"parity=Y zero_copy={'Y' if s['zero_copy'] else 'N'} "
             f"tok_s={a['throughput_tok_s']:.1f} "
             f"vs_best={s['best_static']}@{s['tok_s_ratio']:.2f}x "
             f"ttft_ratio={s['ttft_ratio']:.2f} "
             f"replans={a['replans']} migrations={a['migrations']} "
             f"final={a['final_plan']}")]


def serving_bench_summary(seed: int = 0, *, trace_out: str = None,
                          metrics_out: str = None) -> dict:
    """The ``BENCH_serving.json`` payload: the headline serving numbers —
    throughput, cold vs warm TTFT, prefix-hit rate, block/token savings
    from the shared-prefix compute-reuse sweep — plus the speculative
    sweep under ``"speculative"`` (parity-asserted; CI gates
    ``tokens_per_step_on > 1``), the sync-vs-async runtime comparison
    under ``"overlap"`` (parity-asserted; CI gates async throughput
    strictly above sync), the int8 block-pool figures under
    ``"int8_kv"`` (CI gates ``kv_capacity_x >= 1.9``), and the adaptive
    re-planning comparison under ``"adaptive"`` (parity- and
    zero-copy-asserted; CI gates adaptive throughput and p50 TTFT
    against the best static leg).  ``trace_out`` / ``metrics_out`` make
    the adaptive sweep also emit the Perfetto trace + Prometheus metrics
    smoke artifacts (measured numbers stay tracing-off)."""
    return {**prefix_reuse_sweep(seed=seed),
            "speculative": speculative_sweep(seed=seed),
            "overlap": overlap_sweep(seed=seed),
            "int8_kv": int8_kv_sweep(seed=seed),
            "adaptive": adaptive_sweep(layers=2, seed=seed,
                                       trace_out=trace_out,
                                       metrics_out=metrics_out)}


def _serving_plans(cfg, slots: int, chunk: int, seq: int, batch: int):
    """The strategy triple as ServingPlans: sequential (1 stage, 1 decode
    replica), spatial (one stage per group, max replicas = all slots), and
    an uneven DSE-searched hybrid plan."""
    from repro.configs import ShapeConfig
    from repro.core import build_graph, ssr_dse
    from repro.plan import lower, lower_serving, uniform_plan

    G = cfg.num_groups
    plans = [
        ("sequential", uniform_plan(G, 1, n_microbatches=1)),
        ("spatial", uniform_plan(G, G, n_microbatches=slots)),
    ]
    # hybrid: an uneven 2-acc layer cut through the DSE customization pass
    g = build_graph(cfg, ShapeConfig("serving_bench", seq, batch, "prefill"))
    blocks = [n.idx for n in g.nodes if n.kind == "block"]
    cut = max((len(blocks) * 3) // 4, 1)         # uneven: ~3/4 vs 1/4
    acc_of = []
    for n in g.nodes:
        if n.kind == "block":
            acc_of.append(0 if blocks.index(n.idx) < cut else 1)
        else:
            acc_of.append(0 if n.kind == "embed" else 1)
    _, _, assign = ssr_dse(g, tuple(acc_of), 8, n_batches=2)
    hybrid = lower(assign, g, mesh_devices=8,
                   n_microbatches=min(2, slots))
    plans.append(("hybrid", hybrid))
    return [(name, lower_serving(p, slots=slots, chunk=chunk))
            for name, p in plans]


def plan_serving_sweep(arch: str = "yi-6b", *, layers: int = 4,
                       slots: int = 4, chunk: int = 4, requests: int = 8,
                       new_tokens: int = 6, rate_rps: float = 20.0,
                       max_seq: int = 64, seed: int = 0) -> List[dict]:
    """Run the same Poisson trace through plan-driven engines (sequential /
    spatial / hybrid ServingPlans); one stats dict per strategy."""
    import jax

    from repro.configs import REGISTRY, reduced
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = reduced(REGISTRY[arch], layers=layers)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    out = []
    for name, splan in _serving_plans(cfg, slots, chunk, max_seq, 8):
        eng = ServingEngine(model, params, slots=slots, max_seq=max_seq,
                            plan=splan)
        # warmup: compile the stage/decode walks outside the measured window
        eng.submit(Request(-1, np.arange(1, 6, dtype=np.int32), 2))
        eng.run()
        eng.reset_stats()
        wall = _drive_poisson(eng, cfg, requests, new_tokens, rate_rps, seed)
        st = eng.stats()
        st.update(strategy=name, slots=slots, wall_s=wall, arch=arch,
                  n_stages=splan.n_stages, replicas=splan.n_replicas,
                  lat_p50_s=float(np.percentile(st["latency_s"], 50)),
                  lat_p95_s=float(np.percentile(st["latency_s"], 95)),
                  ttft_p50_s=float(np.percentile(st["ttft_s"], 50)))
        out.append(st)
    return out


def served_design_points(stats: Sequence[dict]):
    """Plan-driven serving measurements on the shared Pareto axes, tagged
    ``source="served"`` (vs "analytic" sweeps and "measured" synthetic
    plan executions)."""
    from repro.core.pareto import DesignPoint

    return [DesignPoint(strategy=s["strategy"], n_acc=s["n_stages"],
                        n_batches=s["replicas"], latency=s["lat_p50_s"],
                        throughput_tops=s["throughput_tok_s"],
                        detail=(f"slots={s['slots']} "
                                f"occ={s['slot_occupancy']:.2f}"),
                        source="served")
            for s in stats]


def _plan_rows(pstats: Sequence[dict]) -> List[Tuple[str, float, str]]:
    out = []
    for s, p in zip(pstats, served_design_points(pstats)):
        name = (f"serving/plan/{s['arch']}/{s['strategy']}"
                f"-{s['n_stages']}stages-{s['replicas']}rep")
        out.append((name, s["lat_p50_s"] * 1e6,
                    f"source={p.source} "
                    f"tok_s={s['throughput_tok_s']:.1f} "
                    f"ttft_p50_ms={s['ttft_p50_s']*1e3:.1f} "
                    f"chunk={s['prefill_chunk']} "
                    f"occupancy={s['slot_occupancy']:.2f}"))
    return out


def rows(seed: int = 0) -> List[Tuple[str, float, str]]:
    """benchmarks/run.py section: ``name,us_per_call,derived`` rows.
    ``seed`` fixes the Poisson arrival trace (reproducible sweeps)."""
    stats = serving_sweep(seed=seed)
    from repro.core.pareto import pareto_front

    front = {p.strategy for p in pareto_front(serving_design_points(stats))}
    out = []
    for s in stats:
        name = f"serving/poisson/{s['arch']}/slots{s['slots']}"
        on_front = f"serving-{s['slots']}slots" in front
        out.append((name, s["lat_p50_s"] * 1e6,
                    f"tok_s={s['throughput_tok_s']:.1f} "
                    f"lat_p95_ms={s['lat_p95_s']*1e3:.1f} "
                    f"ttft_p50_ms={s['ttft_p50_s']*1e3:.1f} "
                    f"occupancy={s['slot_occupancy']:.2f} "
                    f"pareto={'Y' if on_front else 'n'}"))
    out += _plan_rows(plan_serving_sweep(seed=seed))
    out += _paged_rows(paged_serving_sweep(seed=seed))
    out += _prefix_rows(prefix_reuse_sweep(seed=seed))
    out += _spec_rows(speculative_sweep(seed=seed))
    out += _overlap_rows(overlap_sweep(seed=seed))
    out += _int8_rows(int8_kv_sweep(seed=seed))
    out += _adaptive_rows(adaptive_sweep(seed=seed))
    return out


def smoke_rows(seed: int = 0) -> List[Tuple[str, float, str]]:
    """`benchmarks/run.py serving --smoke`: the plan-driven strategy sweep,
    a paged-vs-dense comparison (token parity asserted, block savings
    reported), and the shared-prefix compute-reuse TTFT sweep at smoke
    size on CPU jax — the per-commit perf artifact's serving rows
    (serving_smoke.json; the reuse sweep also lands in
    BENCH_serving.json)."""
    rows = _plan_rows(plan_serving_sweep(
        requests=6, new_tokens=4, slots=2, chunk=4, seed=seed))
    rows += _paged_rows(paged_serving_sweep(
        requests=6, new_tokens=4, slots=2, page_sizes=(4,), seed=seed))
    rows += _prefix_rows(prefix_reuse_sweep(requests=4, seed=seed))
    rows += _spec_rows(speculative_sweep(requests=4, seed=seed))
    rows += _overlap_rows(overlap_sweep(seed=seed))
    rows += _int8_rows(int8_kv_sweep(requests=4, seed=seed))
    rows += _adaptive_rows(adaptive_sweep(layers=2, seed=seed))
    return rows
