"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

  table5      — perf per model x batch vs paper's on-board numbers
  table6      — throughput under latency constraints (seq/spatial/hybrid)
  table7      — analytical model vs paper measurements per #accs
  fig2        — latency-throughput Pareto front
  fig10       — EA vs exhaustive search efficiency
  ablation    — §5.2.6 step-by-step feature gains
  q1          — §6 cross-platform (Stratix 10 NX) modeling
  roofline    — §Roofline terms per (arch x shape) from the dry-run JSONs
  micro       — measured CPU microbenchmarks of the runnable substrate
  serving     — measured latency/throughput under Poisson arrivals per
                slot count (continuous-batching engine)

``--smoke`` instead runs the fast tier-1 test subset in < 60 s: the
suite minus the ``slow``-marked 8-device subprocess tests AND minus the
two compile-heavy sweep files (test_models.py, test_perf_paths.py) —
the full gate remains ``pytest -q``.
"""
from __future__ import annotations

import os
import subprocess
import sys


def micro_rows():
    """Measured wall-time microbenchmarks (CPU, reduced configs): the
    runnable-path sanity numbers."""
    import jax
    import jax.numpy as jnp

    from benchmarks.common import timed
    from repro.configs import REGISTRY, ShapeConfig, reduced
    from repro.data import SyntheticLM
    from repro.models import build_model
    from repro.training import AdamW, make_train_step

    rows = []
    for arch in ("yi-6b", "qwen2-moe-a2.7b", "xlstm-125m"):
        cfg = reduced(REGISTRY[arch])
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        data = SyntheticLM(cfg, ShapeConfig("b", 64, 4, "train"))
        batch = jax.tree.map(jnp.asarray, data.batch_at(0))
        opt = AdamW(warmup_steps=1, total_steps=100)
        step = jax.jit(make_train_step(model, opt, remat=False))
        st = opt.init(params)
        jax.block_until_ready(step(params, st, batch))  # warmup/compile
        (_, us) = timed(lambda: jax.block_until_ready(
            step(params, st, batch)), repeat=5)
        tok = 64 * 4
        rows.append((f"micro/train_step/{arch}", us,
                     f"tokens_per_s={tok/(us/1e6):.0f} (reduced cfg; CPU)"))
    return rows


def smoke() -> int:
    """Fast tier-1 subset (< 60 s): the suite minus the ``slow``-marked
    8-device subprocess tests and the two compile-heavy sweep files
    (test_models ~2 min of jit compiles, test_perf_paths ~30 s).  The full
    tier-1 gate stays ``pytest -q``."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "pytest", "-q", "-m", "not slow",
           "-p", "no:cacheprovider",
           "--ignore", os.path.join("tests", "test_models.py"),
           "--ignore", os.path.join("tests", "test_perf_paths.py"),
           "tests"]
    return subprocess.run(cmd, cwd=repo, env=env).returncode


def main() -> None:
    if "--smoke" in sys.argv[1:]:
        sys.exit(smoke())
    from benchmarks import paper_tables as P
    from benchmarks.roofline import roofline_rows
    from benchmarks.serving import rows as serving_rows
    from benchmarks.tpu_tradeoff import rows as tpu_rows

    sections = {
        "table5": P.table5,
        "table6": P.table6,
        "table7": P.table7,
        "fig2": P.fig2,
        "fig10": P.fig10,
        "ablation": P.step_by_step,
        "q1": P.q1_cross_platform,
        "tpu_tradeoff": tpu_rows,
        "roofline": roofline_rows,
        "micro": micro_rows,
        "serving": serving_rows,
    }
    only = sys.argv[1:] or list(sections)
    print("name,us_per_call,derived")
    for key in only:
        fn = sections.get(key)
        if fn is None:
            continue
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # keep the harness running
            print(f"{key}/ERROR,0,{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
