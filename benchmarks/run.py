"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

  table5      — perf per model x batch vs paper's on-board numbers
  table6      — throughput under latency constraints (seq/spatial/hybrid)
  table7      — analytical model vs paper measurements per #accs
  fig2        — latency-throughput Pareto front
  fig10       — EA vs exhaustive search efficiency
  ablation    — §5.2.6 step-by-step feature gains
  q1          — §6 cross-platform (Stratix 10 NX) modeling
  roofline    — §Roofline terms per (arch x shape) from the dry-run JSONs
  micro       — measured CPU microbenchmarks of the runnable substrate
  serving     — measured latency/throughput under Poisson arrivals per
                slot count (continuous-batching engine)
  plan        — EA-searched assignments lowered to ExecutionPlans and
                executed: measured points next to analytic ones

``--seed N`` threads a seed through every stochastic section (serving
Poisson trace, EA searches) so sweeps are reproducible run-to-run.

``--smoke`` instead runs the fast tier-1 test subset in < 60 s: the
suite minus the ``slow``-marked 8-device subprocess tests AND minus the
two compile-heavy sweep files (test_models.py, test_perf_paths.py) —
the full gate remains ``pytest -q``.  ``--smoke-json PATH`` additionally
writes a machine-readable summary (and the plan measured-vs-analytic
rows) so CI can archive the perf trajectory per commit.

``<section> --smoke`` (e.g. ``serving --smoke``) instead runs a smoke-
sized variant of that section — for ``serving``, the plan-driven strategy
sweep (sequential / spatial / small hybrid ServingPlan) plus a
paged-vs-dense cache-layout comparison (token parity asserted, block
savings reported) on CPU jax — so plan-serving throughput and the paged
block-pool figures land in the per-commit perf artifact too.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

# self-sufficient invocation (`python benchmarks/run.py ...` from anywhere):
# the repo root (for `benchmarks.*`) and src (for `repro.*`) on sys.path.
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def micro_rows(seed: int = 0):
    """Measured wall-time microbenchmarks (CPU, reduced configs): the
    runnable-path sanity numbers."""
    import jax
    import jax.numpy as jnp

    from benchmarks.common import timed
    from repro.configs import REGISTRY, ShapeConfig, reduced
    from repro.data import SyntheticLM
    from repro.models import build_model
    from repro.training import AdamW, make_train_step

    rows = []
    for arch in ("yi-6b", "qwen2-moe-a2.7b", "xlstm-125m"):
        cfg = reduced(REGISTRY[arch])
        model = build_model(cfg)
        params = model.init(jax.random.key(seed))
        data = SyntheticLM(cfg, ShapeConfig("b", 64, 4, "train"))
        batch = jax.tree.map(jnp.asarray, data.batch_at(0))
        opt = AdamW(warmup_steps=1, total_steps=100)
        step = jax.jit(make_train_step(model, opt, remat=False))
        st = opt.init(params)
        jax.block_until_ready(step(params, st, batch))  # warmup/compile
        (_, us) = timed(lambda: jax.block_until_ready(
            step(params, st, batch)), repeat=5)
        tok = 64 * 4
        rows.append((f"micro/train_step/{arch}", us,
                     f"tokens_per_s={tok/(us/1e6):.0f} (reduced cfg; CPU)"))
    return rows


def smoke(json_path: str = "", seed: int = 0) -> int:
    """Fast tier-1 subset (< 60 s): the suite minus the ``slow``-marked
    8-device subprocess tests and the two compile-heavy sweep files
    (test_models ~2 min of jit compiles, test_perf_paths ~30 s).  The full
    tier-1 gate stays ``pytest -q``.

    json_path: optional output file recording the run (returncode, wall
    seconds, plus the measured-vs-analytic plan rows) — uploaded as a CI
    artifact so the perf trajectory is tracked per commit."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "pytest", "-q", "-m", "not slow",
           "-p", "no:cacheprovider",
           "--ignore", os.path.join("tests", "test_models.py"),
           "--ignore", os.path.join("tests", "test_perf_paths.py"),
           "tests"]
    t0 = time.perf_counter()
    rc = subprocess.run(cmd, cwd=repo, env=env).returncode
    wall = time.perf_counter() - t0
    if json_path:
        summary = {"suite": "smoke", "returncode": rc,
                   "wall_s": round(wall, 2), "seed": seed}
        try:
            from repro.backend import compat
            summary["jax"] = ".".join(map(str, compat.jax_version()))
            from benchmarks.plan_bench import rows as plan_rows
            summary["plan_rows"] = [
                {"name": n, "us_per_call": round(us, 1), "derived": d}
                for n, us, d in plan_rows(seed=seed)]
        except Exception as e:  # keep the artifact even if the bench dies
            summary["plan_error"] = f"{type(e).__name__}: {e}"
        os.makedirs(os.path.dirname(os.path.abspath(json_path)),
                    exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"[smoke] wrote {json_path}")
    return rc


def smoke_sections(sections, json_path: str = "", seed: int = 0) -> int:
    """Smoke-sized section runs (``run.py <section> --smoke``): print the
    rows and optionally archive them as JSON (CI perf artifact).  The
    ``serving`` section additionally writes ``BENCH_serving.json`` (next
    to ``json_path``, else the cwd): the headline serving numbers —
    throughput, cold vs warm TTFT, prefix-hit rate, block savings — that
    CI archives and gates on (warm TTFT must beat cold).  It also emits
    the observability smoke artifacts ``trace_smoke.json`` (Perfetto) and
    ``metrics.prom`` (Prometheus text) from one extra traced replay of
    the adaptive trace — measured numbers stay tracing-off."""
    from benchmarks.serving import serving_bench_summary
    from benchmarks.serving import smoke_rows as serving_smoke

    known = {"serving": serving_smoke}
    unknown = [s for s in sections if s not in known]
    if unknown:
        print(f"no smoke variant for section(s) {unknown}; "
              f"choose from {list(known)}")
        return 2
    rc = 0
    summary = {"suite": "smoke-sections", "seed": seed, "sections": {}}
    print("name,us_per_call,derived")
    for key in sections:
        try:
            rows = known[key](seed=seed)
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}")
            summary["sections"][key] = [
                {"name": n, "us_per_call": round(us, 1), "derived": d}
                for n, us, d in rows]
        except Exception as e:      # pragma: no cover - keep harness alive
            print(f"{key}/ERROR,0,{type(e).__name__}: {e}")
            summary["sections"][key] = {"error": f"{type(e).__name__}: {e}"}
            rc = 1
    if "serving" in sections:
        out_dir = (os.path.dirname(os.path.abspath(json_path)) if json_path
                   else os.getcwd())
        bench_path = os.path.join(out_dir, "BENCH_serving.json")
        trace_path = os.path.join(out_dir, "trace_smoke.json")
        metrics_path = os.path.join(out_dir, "metrics.prom")
        try:
            os.makedirs(out_dir, exist_ok=True)
            bench = serving_bench_summary(seed=seed, trace_out=trace_path,
                                          metrics_out=metrics_path)
            with open(bench_path, "w") as f:
                json.dump(bench, f, indent=2)
            print(f"[smoke] wrote {bench_path}")
            print(f"[smoke] wrote {trace_path} and {metrics_path}")
        except Exception as e:      # pragma: no cover - keep harness alive
            print(f"serving/BENCH_ERROR,0,{type(e).__name__}: {e}")
            rc = 1
    if json_path:
        os.makedirs(os.path.dirname(os.path.abspath(json_path)),
                    exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"[smoke] wrote {json_path}")
    return rc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("sections", nargs="*", help="sections to run (default all)")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for stochastic sections (serving, EA, plan)")
    ap.add_argument("--smoke", action="store_true",
                    help="run the fast tier-1 test subset instead")
    ap.add_argument("--smoke-json", default="",
                    help="with --smoke: write a JSON summary here")
    args = ap.parse_args()

    if args.smoke:
        if args.sections:
            sys.exit(smoke_sections(args.sections,
                                    json_path=args.smoke_json,
                                    seed=args.seed))
        sys.exit(smoke(json_path=args.smoke_json, seed=args.seed))

    from benchmarks import paper_tables as P
    from benchmarks.plan_bench import rows as plan_rows
    from benchmarks.roofline import roofline_rows
    from benchmarks.serving import rows as serving_rows
    from benchmarks.tpu_tradeoff import rows as tpu_rows

    # insertion order is the run order; the bool marks seed-taking sections
    sections = {
        "table5": (P.table5, False),
        "table6": (P.table6, True),
        "table7": (P.table7, False),
        "fig2": (P.fig2, True),
        "fig10": (P.fig10, True),
        "ablation": (P.step_by_step, False),
        "q1": (P.q1_cross_platform, False),
        "tpu_tradeoff": (tpu_rows, False),
        "roofline": (roofline_rows, False),
        "micro": (micro_rows, True),
        "serving": (serving_rows, True),
        "plan": (plan_rows, True),
    }
    unknown = [k for k in args.sections if k not in sections]
    if unknown:
        sys.exit(f"unknown section(s) {unknown}; "
                 f"choose from {list(sections)}")
    only = args.sections or list(sections)
    print("name,us_per_call,derived")
    for key in only:
        fn, takes_seed = sections[key]
        try:
            rows = fn(seed=args.seed) if takes_seed else fn()
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # keep the harness running
            print(f"{key}/ERROR,0,{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
