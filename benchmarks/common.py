"""Shared benchmark utilities: paper-platform chip models, timers, CSV."""
from __future__ import annotations

import time
from dataclasses import replace

from repro.core.hw import VCK190, Chip

# The paper partitions one VCK190 into up to ~#layer accelerators.  Our cost
# model partitions "chips"; model the board as 8 partitionable units (50 AIEs
# each).  On-chip forwarding rides PLIO/NoC — far faster than the 25.6 GB/s
# DDR — hence the higher ici_bw (this asymmetry is exactly the paper's
# on-chip-forwarding argument).
VCK190_UNIT = Chip(
    name="vck190-unit",
    peak_flops=VCK190.peak_flops / 8,      # 12.8 INT8 TOPS per unit
    hbm_bw=VCK190.hbm_bw / 8,              # DDR share
    ici_bw=16e9,                           # on-chip NoC/PLIO per unit
    ici_links_per_axis=2,
    hbm_bytes=VCK190.hbm_bytes / 8,
    vmem_bytes=VCK190.vmem_bytes,
    vpu_flops=VCK190.vpu_flops / 8,
    weights_resident=True,       # AIE local-memory weight pinning
    tile=32,                     # AIE core tile granularity
    max_eff=0.70,                # CHARM-reported AIE MM efficiency
    fixed_config=True,           # one array config per acc (bitstream)
)

STRATIX_UNIT = Chip(
    name="stratix10nx-unit",
    peak_flops=143e12 / 8,
    hbm_bw=512e9 / 8,
    ici_bw=16e9,
    ici_links_per_axis=2,
    hbm_bytes=2 * 1024**3,
    vmem_bytes=2 * 1024**2,
    vpu_flops=2e12 / 8,
    weights_resident=True,       # 16MB on-chip SRAM (BrainWave-style)
    tile=32,
    max_eff=0.70,
    fixed_config=True,
)

BOARD_UNITS = 8


def timed(fn, *args, repeat: int = 1, **kw):
    """Returns (result, us_per_call)."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
