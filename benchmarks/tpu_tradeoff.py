"""TPU-side SSR latency-throughput tradeoff — the paper's technique applied
to the assigned LM architectures on the v5e pod (256 chips).

For a heterogeneous stack (jamba: mamba/attention/MoE layer shapes differ;
qwen2-moe: router+experts vs attention) the hybrid layer→acc search has
room to specialize stage submeshes; for a uniform dense LM (yi-6b) it
should collapse onto sequential (DESIGN.md §7).  This is the TPU analogue
of paper Table 6.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.configs import SHAPES, get_config
from repro.core import (build_graph, pareto_front, sequential_assignment,
                        simulate, strategy_points)
from repro.core.hw import TPU_V5E

CELLS = [
    ("jamba-1.5-large-398b", "prefill_32k"),
    ("qwen2-moe-a2.7b", "prefill_32k"),
    ("yi-6b", "prefill_32k"),
]


def rows() -> List[Tuple[str, float, str]]:
    out = []
    for arch, shape in CELLS:
        g = build_graph(get_config(arch), SHAPES[shape])
        t0 = time.perf_counter()
        pts = strategy_points(g, 256, hw=TPU_V5E, batches=(1, 2, 4),
                              hybrid_accs=(2, 4), ea_iters=3)
        us = (time.perf_counter() - t0) * 1e6
        front = pareto_front(pts)
        seq = [p for p in pts if p.strategy == "sequential"
               and p.n_batches == 1][0]
        best = max(pts, key=lambda p: p.throughput_tops)
        out.append((
            f"tpu_tradeoff/{arch}", us,
            f"seq_lat_s={seq.latency:.3f} seq_tops={seq.throughput_tops:.0f} "
            f"best={best.strategy}(accs={best.n_acc},b={best.n_batches}) "
            f"best_tops={best.throughput_tops:.0f} "
            f"hybrid_gain={best.throughput_tops/seq.throughput_tops:.2f}x "
            f"front={[p.strategy for p in front]}"))
    return out
