"""Plan lowering benchmark: measured vs analytic design points.

Searches hybrid Layer→Acc assignments (the EA) on a reduced-size arch,
lowers each winner to an ``ExecutionPlan``, *executes* it stage-by-stage
on the local backend (``repro.plan.validate``), and emits the measured
points (``source="measured"``) next to the analytic ones
(``source="analytic"``) on the shared Pareto axes — the search → plan →
execute loop closed end-to-end.

    PYTHONPATH=src python benchmarks/run.py plan [--seed N]
"""
from __future__ import annotations

from typing import List, Tuple


def plan_points(arch: str = "yi-6b", *, layers: int = 4, batch: int = 8,
                seq: int = 32, chips: int = 8, seed: int = 0,
                stage_counts=(1, 2, 3), repeat: int = 3):
    """Returns (analytic_points, measured_points, plans) for the sweep."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import REGISTRY, ShapeConfig, reduced
    from repro.core import build_graph, evolutionary_search, ssr_dse
    from repro.core.assignment import contiguous_assignment
    from repro.core.pareto import DesignPoint
    from repro.models import build_model
    from repro.plan import lower, measured_design_points, predict_plan

    cfg = reduced(REGISTRY[arch], layers=layers)
    shape = ShapeConfig("plan_bench", seq, batch, "prefill")
    g = build_graph(cfg, shape)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(seed)
    batch_in = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, (batch, seq)), jnp.int32)}
    # microbatch count must divide the batch: deepest divisor <= 4
    mb = next(m for m in (4, 2, 1) if batch % m == 0)

    # forced contiguous partitions per stage count (the strategy sweep) +
    # the EA winner (which may legitimately collapse onto fewer accs)
    genomes = [contiguous_assignment(g, n_acc, chips).acc_of
               for n_acc in stage_counts]
    genomes.append(evolutionary_search(
        g, chips, n_acc=max(stage_counts), n_batches=2, n_pop=6,
        n_child=6, n_iter=3, seed=seed).assignment.acc_of)

    analytic, plans = [], []
    for acc_of in genomes:
        _, _, assign = ssr_dse(g, acc_of, chips, n_batches=2)
        plan = lower(assign, g, mesh_devices=chips, n_microbatches=mb)
        plans.append(plan)
        pred = predict_plan(plan, g)
        analytic.append(DesignPoint(
            strategy="hybrid" if plan.n_stages > 1 else "sequential",
            n_acc=plan.n_stages, n_batches=plan.total_microbatches,
            latency=pred["makespan_s"],
            throughput_tops=pred["throughput_tops"],
            detail=f"waste={pred['padding_waste']:.2f}"))
    measured = measured_design_points(model, params, batch_in, g, plans,
                                      repeat=repeat)
    return analytic, measured, plans


def rows(seed: int = 0) -> List[Tuple[str, float, str]]:
    """benchmarks/run.py section: analytic + measured rows per plan."""
    analytic, measured, plans = plan_points(seed=seed)
    out = []
    labels = [f"{p.n_stages}stages" for p in plans[:-1]] + \
        [f"ea_{plans[-1].n_stages}stages"]
    for a, m, p, name in zip(analytic, measured, plans,
                             (f"plan/{l}" for l in labels)):
        out.append((f"{name}/analytic", a.latency * 1e6,
                    f"source={a.source} tops={a.throughput_tops:.2f} "
                    f"mb={p.total_microbatches} {a.detail}"))
        out.append((f"{name}/measured", m.latency * 1e6,
                    f"source={m.source} tops={m.throughput_tops:.4f} "
                    f"mb={p.total_microbatches} {m.detail}"))
    return out
