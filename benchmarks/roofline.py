"""§Roofline table: read the dry-run JSONs and report the three terms per
(arch × shape), the dominant bottleneck, and MODEL_FLOPS/HLO ratio."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Tuple


def load_results(directory: str = "results/dryrun", tag: str = "sp"
                 ) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(directory, f"*__{tag}.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def roofline_rows(directory: str = "results/dryrun"
                  ) -> List[Tuple[str, float, str]]:
    rows = []
    for res in load_results(directory):
        name = f"roofline/{res['arch']}/{res['shape']}"
        if res.get("status") == "skipped":
            rows.append((name, 0.0, f"SKIPPED: {res['reason'][:60]}"))
            continue
        r = res["roofline"]
        terms = {"compute": r["compute_s"], "memory": r["memory_s"],
                 "collective": r["collective_s"]}
        dom = max(terms, key=terms.get)
        bound = max(terms.values())
        frac = terms["compute"] / bound if bound else 0.0
        rows.append((
            name, res.get("compile_s", 0) * 1e6,
            f"compute_s={terms['compute']:.4g} memory_s={terms['memory']:.4g} "
            f"collective_s={terms['collective']:.4g} dominant={dom} "
            f"roofline_frac={frac:.3f} "
            f"model/hlo={r['model_to_hlo_ratio']:.3f}"))
    return rows


def summary(directory: str = "results/dryrun"):
    """Aggregate: count per dominant term, worst cells (hillclimb pick)."""
    res = [r for r in load_results(directory) if r.get("status") == "ok"]
    doms = {}
    worst = []
    for r in res:
        rf = r["roofline"]
        terms = {"compute": rf["compute_s"], "memory": rf["memory_s"],
                 "collective": rf["collective_s"]}
        d = max(terms, key=terms.get)
        doms[d] = doms.get(d, 0) + 1
        bound = max(terms.values())
        frac = terms["compute"] / bound if bound else 0.0
        worst.append((frac, r["arch"], r["shape"], d))
    worst.sort()
    return doms, worst[:5]
