"""Paper reproductions: Table 5/6/7, Fig 2, Fig 10 on the VCK190 board
model (the paper's own platform constants), plus the cross-platform §6-Q1
check on Stratix 10 NX.

Every function returns a list of (name, us_per_call, derived) rows.
"""
from __future__ import annotations

import math
import time
from typing import List, Tuple

from benchmarks.common import (BOARD_UNITS, STRATIX_UNIT, VCK190_UNIT, emit,
                               timed)
from repro.configs.deit import DEIT_160, DEIT_256, DEIT_T, LV_VIT_T, vit_shape
from repro.core import (Features, build_graph, evolutionary_search,
                        exhaustive_search, pareto_front,
                        sequential_assignment, simulate, spatial_assignment,
                        ssr_dse, strategy_points)
from repro.core.pareto import best_under_latency

PAPER_MODELS = [DEIT_T, DEIT_160, DEIT_256, LV_VIT_T]

# Paper Table 5, SSR on VCK190 (latency ms / throughput TOPS) per batch.
PAPER_T5 = {
    "deit-t": {1: (0.22, 10.90), 3: (0.39, 18.62), 6: (0.54, 26.70)},
    "deit-160": {1: (0.21, 8.19), 3: (0.37, 14.92), 6: (0.50, 20.90)},
    "deit-256": {1: (0.40, 10.30), 3: (0.66, 18.73), 6: (0.98, 25.22)},
    "lv-vit-t": {1: (0.38, 8.21), 3: (0.62, 15.10), 6: (0.85, 22.03)},
}

# Paper Table 7: #accs -> measured latency (ms), DeiT-T batch 6.
PAPER_T7 = {1: 1.30, 2: 1.08, 3: 0.85, 4: 0.83, 5: 0.79, 6: 0.54}

# Paper Table 6: latency constraint (ms) -> best TOPS per strategy.
PAPER_T6 = {2.0: (11.17, 26.70, 26.70), 1.0: (11.12, 26.70, 26.70),
            0.5: (11.05, 19.37, 19.37), 0.4: (10.90, None, 18.56)}


def _board_points(cfg, batch, n_acc, *, feats=Features()):
    """SSR design point on the 8-unit VCK190 board model: op-granularity
    graph (paper Fig. 4) + role-based acc assignment (paper Fig. 9)."""
    from repro.core.assignment import role_assignment
    g = build_graph(cfg, vit_shape(batch), granularity="op")
    acc_of = role_assignment(g, BOARD_UNITS, max_accs=n_acc).acc_of
    lat, thr, assign = ssr_dse(
        g, acc_of, BOARD_UNITS, n_batches=batch,
        hw=VCK190_UNIT, feats=feats)
    return lat, thr, g


def _contig(n_nodes, n_acc):
    out = []
    per = max(n_nodes // n_acc, 1)
    for i in range(n_nodes):
        out.append(min(i // per, n_acc - 1))
    return tuple(out)


def table5() -> List[Tuple[str, float, str]]:
    """SSR throughput per model x batch — model vs the paper's on-board
    measurements (the paper-claims validation)."""
    rows = []
    for cfg in PAPER_MODELS:
        for batch in (1, 3, 6):
            t0 = time.perf_counter()
            lat, thr, g = _board_points(cfg, batch, n_acc=min(batch, 6))
            us = (time.perf_counter() - t0) * 1e6
            p_lat, p_thr = PAPER_T5[cfg.name][batch]
            rows.append((
                f"table5/{cfg.name}/b{batch}", us,
                f"lat_ms={lat*1e3:.3f} thr_tops={thr:.2f} "
                f"paper_lat_ms={p_lat} paper_tops={p_thr} "
                f"thr_ratio={thr/p_thr:.2f}"))
    return rows


def table6(seed: int = 0) -> List[Tuple[str, float, str]]:
    """Throughput under latency constraints: sequential vs spatial vs
    hybrid — the Pareto-dominance claim."""
    rows = []
    g6 = build_graph(DEIT_T, vit_shape(6), granularity="op")
    t0 = time.perf_counter()
    pts = strategy_points(g6, BOARD_UNITS, hw=VCK190_UNIT,
                          batches=(1, 2, 3, 4, 6),
                          hybrid_accs=(2, 3, 4), ea_iters=4, seed=seed)
    build_us = (time.perf_counter() - t0) * 1e6
    for lat_ms, (p_seq, p_spa, p_hyb) in PAPER_T6.items():
        cons = lat_ms * 1e-3
        seq = best_under_latency(
            [p for p in pts if p.strategy == "sequential"], cons)
        spa = best_under_latency(
            [p for p in pts if p.strategy == "spatial"], cons)
        hyb = best_under_latency(pts, cons, strategy="hybrid")
        fmt = lambda p: f"{p.throughput_tops:.2f}" if p else "x"
        rows.append((
            f"table6/lat<{lat_ms}ms", build_us / len(PAPER_T6),
            f"seq={fmt(seq)} spatial={fmt(spa)} hybrid={fmt(hyb)} "
            f"paper=({p_seq}/{p_spa or 'x'}/{p_hyb}) "
            f"hybrid_dominates={bool(hyb and (not seq or hyb.throughput_tops >= seq.throughput_tops - 1e-9) and (not spa or hyb.throughput_tops >= spa.throughput_tops - 1e-9))}"))
    return rows


def table7() -> List[Tuple[str, float, str]]:
    """Analytical model vs paper's measured on-board latency per #accs
    (DeiT-T, batch 6): the <5% model-fidelity claim, here reported as our
    model's relative trend error vs their measurements."""
    rows = []
    ours = {}
    for n_acc in range(1, 7):
        t0 = time.perf_counter()
        lat, thr, _ = _board_points(DEIT_T, 6, n_acc)
        us = (time.perf_counter() - t0) * 1e6
        ours[n_acc] = lat * 1e3
        rows.append((f"table7/accs{n_acc}", us,
                     f"est_ms={lat*1e3:.3f} paper_ms={PAPER_T7[n_acc]}"))
    # trend fidelity: normalized-latency correlation against paper curve
    import numpy as np
    a = np.array([ours[i] for i in range(1, 7)])
    b = np.array([PAPER_T7[i] for i in range(1, 7)])
    a, b = a / a[0], b / b[0]
    err = float(np.mean(np.abs(a - b) / b)) * 100
    corr = float(np.corrcoef(a, b)[0, 1])
    rows.append(("table7/trend", 0.0,
                 f"mean_rel_err_pct={err:.1f} corr={corr:.3f}"))
    return rows


def fig2(seed: int = 0) -> List[Tuple[str, float, str]]:
    """Latency-throughput Pareto front: hybrid must dominate."""
    g = build_graph(DEIT_T, vit_shape(6), granularity="op")
    t0 = time.perf_counter()
    pts = strategy_points(g, BOARD_UNITS, hw=VCK190_UNIT,
                          batches=(1, 2, 4, 6), hybrid_accs=(2, 4),
                          ea_iters=3, seed=seed)
    us = (time.perf_counter() - t0) * 1e6
    front = pareto_front(pts)
    n_hybrid = sum(1 for p in front if p.strategy == "hybrid")
    rows = [("fig2/pareto", us,
             f"points={len(pts)} front={len(front)} "
             f"hybrid_on_front={n_hybrid} "
             f"front_lat_ms={[round(p.latency*1e3,3) for p in front[:6]]} "
             f"front_tops={[round(p.throughput_tops,1) for p in front[:6]]}")]
    return rows


def fig10(seed: int = 0) -> List[Tuple[str, float, str]]:
    """Search efficiency: EA + inter-acc-aware pruning vs exhaustive."""
    g = build_graph(DEIT_T, vit_shape(6), granularity="op")
    t0 = time.perf_counter()
    ea = evolutionary_search(g, BOARD_UNITS, n_acc=4, n_batches=6,
                             n_pop=10, n_child=10, n_iter=6, seed=seed,
                             hw=VCK190_UNIT)
    ea_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    ex = exhaustive_search(g, BOARD_UNITS, n_acc=4, n_batches=6,
                           max_evals=600, hw=VCK190_UNIT)
    ex_s = time.perf_counter() - t0
    return [("fig10/ea", ea_s * 1e6,
             f"best_tops={ea.throughput:.2f} evals={ea.evaluations} "
             f"wall_s={ea_s:.2f}"),
            ("fig10/exhaustive", ex_s * 1e6,
             f"best_tops={ex.throughput:.2f} evals={ex.evaluations} "
             f"wall_s={ex_s:.2f} "
             f"ea_speedup_evals={ex.evaluations/max(ea.evaluations,1):.2f}")]


def step_by_step() -> List[Tuple[str, float, str]]:
    """§5.2.6 feature ablation on DeiT-T batch=6 (12ms -> 0.54ms story)."""
    g = build_graph(DEIT_T, vit_shape(6), granularity="op")
    rows = []
    base = Features(onchip_forwarding=False, fine_grained_pipeline=False)
    f1 = Features(onchip_forwarding=True, fine_grained_pipeline=False)
    f13 = Features(onchip_forwarding=True, fine_grained_pipeline=True)
    # baseline: spatial accs, no forwarding, no pipeline
    spa = spatial_assignment(g, BOARD_UNITS)
    seq = sequential_assignment(g, BOARD_UNITS)
    t0 = time.perf_counter()
    l_base = simulate(g, spa, 6, hw=VCK190_UNIT, feats=base).latency
    l_f1 = simulate(g, spa, 6, hw=VCK190_UNIT, feats=f1).latency
    l_seq1 = simulate(g, seq, 6, hw=VCK190_UNIT, feats=f1).latency
    l_all = simulate(g, spa, 6, hw=VCK190_UNIT, feats=f13).latency
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("ablation/step_by_step", us,
                 f"baseline_ms={l_base*1e3:.2f} +forwarding_ms={l_f1*1e3:.2f} "
                 f"+pipeline_ms={l_all*1e3:.2f} "
                 f"forwarding_gain={l_base/l_f1:.2f}x "
                 f"pipeline_gain={l_f1/l_all:.2f}x paper=(3.4x,2.7x)"))
    return rows


def q1_cross_platform() -> List[Tuple[str, float, str]]:
    """§6 Q1: SSR mapped onto Intel Stratix 10 NX — paper models 0.49ms for
    DeiT-T batch 6 (vs 0.54ms on VCK190)."""
    g = build_graph(DEIT_T, vit_shape(6), granularity="op")
    t0 = time.perf_counter()
    from repro.core.assignment import role_assignment
    lat, thr, _ = ssr_dse(g, role_assignment(g, BOARD_UNITS,
                                             max_accs=6).acc_of, BOARD_UNITS,
                          n_batches=6, hw=STRATIX_UNIT)
    us = (time.perf_counter() - t0) * 1e6
    return [("q1/stratix10nx", us,
             f"est_ms={lat*1e3:.3f} paper_ms=0.49 thr_tops={thr:.2f}")]
